"""Beyond-paper ablation: aggregation-weight shape under motion blur.

The paper's Eq. 11 penalizes blur LINEARLY and its weight spread
collapses as 1/N with fleet size. We compare, at equal everything else:

    flsimco  — w ∝ (ΣL − L_n)/ΣL            (the paper)
    softmax  — w ∝ softmax(−L/T)            (ours; N-scale-free)
    inverse  — w ∝ 1/(L+eps)                (inverse-variance flavored)
    fedavg   — uniform                       (control)

Metric: loss-gradient std (paper Fig. 6 stability statistic) + final
loss, short Non-IID runs.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import build_scenario, emit, save_json
from repro.core import scenario as scn
from repro.core.federation import gradient_std


def main(args=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--vehicles", type=int, default=8)
    ap.add_argument("--per-round", type=int, default=4)
    ap.add_argument("--batch", type=int, default=48)
    ap.add_argument("--n-per-class", type=int, default=60)
    a = ap.parse_args(args)

    out = {}
    for agg in ("flsimco", "softmax", "inverse", "fedavg"):
        sc = build_scenario(a.vehicles, a.n_per_class, iid=False, alpha=0.1,
                            min_per_client=30, aggregator=agg,
                            vehicles_per_round=a.per_round,
                            batch_size=a.batch, rounds=a.rounds, lr=0.5)
        t0 = time.time()
        _, hist = scn.run(sc)
        losses = [h["loss"] for h in hist]
        out[agg] = {"grad_std": gradient_std(losses),
                    "final_loss": float(np.mean(losses[-2:])),
                    "losses": losses}
        emit(f"beyond/weighting/{agg}",
             (time.time() - t0) * 1e6 / max(a.rounds, 1),
             f"grad_std={out[agg]['grad_std']:.4f};"
             f"final={out[agg]['final_loss']:.4f}")
    save_json("beyond_weighting.json", out)
    return out


if __name__ == "__main__":
    main()
