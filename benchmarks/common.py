"""Shared benchmark utilities: world construction + CSV emission."""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def emit(name: str, us_per_call: float, derived: str = ""):
    """The harness's CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")


def save_json(name: str, payload):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    with open(os.path.join(RESULTS_DIR, name), "w") as f:
        json.dump(payload, f, indent=1)


def build_world(n_vehicles: int, n_per_class: int, iid: bool, alpha: float,
                seed: int = 0, min_per_client: int = 0):
    from repro.configs.base import get_config
    from repro.data.synthetic import (make_dataset, partition_dirichlet,
                                      partition_iid)
    from repro.models.resnet import init_resnet
    x, y = make_dataset(n_per_class=n_per_class, seed=seed)
    if iid:
        parts = partition_iid(y, n_vehicles, seed=seed)
    else:
        parts = partition_dirichlet(y, n_vehicles, alpha=alpha,
                                    min_per_client=min_per_client, seed=seed)
    tree = init_resnet(get_config("resnet18-cifar"),
                       jax.random.PRNGKey(seed))
    return x, y, parts, tree


def build_scenario(n_vehicles: int, n_per_class: int, iid: bool,
                   alpha: float = 0.1, seed: int = 0,
                   min_per_client: int = 0, **scenario_kwargs):
    """Declarative world construction: every fig*/beyond driver describes
    its experiment as one `Scenario` (data/model built lazily inside)."""
    from repro.core.scenario import Scenario
    return Scenario(partitioner="iid" if iid else "dirichlet", alpha=alpha,
                    n_per_class=n_per_class, min_per_client=min_per_client,
                    data_seed=seed, n_vehicles=n_vehicles, seed=seed,
                    **scenario_kwargs)


def probe_accuracy(tree, x, y, n_train=600, n_test=300):
    from repro.eval.probe import encode, knn_top1
    n_train = min(n_train, int(0.8 * len(x)))
    n_test = min(n_test, len(x) - n_train)
    f_tr = encode(tree, x[:n_train])
    f_te = encode(tree, x[n_train:n_train + n_test])
    return knn_top1(f_tr, y[:n_train], f_te, y[n_train:n_train + n_test])
