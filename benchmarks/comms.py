"""Comms codec benchmark: bytes/round and codec latency at fleet scale.

The measured headline for the delta-compressed comms tier
(src/repro/comms/): for cohorts of 1k-10k vehicles/round (the small
synthetic fleet trees of benchmarks/multi_rsu.py — the wire cost scales
with params x vehicles, not with client FLOPs), account the bytes every
codec moves per round and time the encode->decode->aggregate stage
against the plain full-tree aggregation.

Byte accounting (per round, V vehicles, P params, f32):

  baseline   V unicast downlinks + V full-tree uplinks = V * 8P bytes.
  delta      the base model theta is SHARED by the whole cohort — one
             4P broadcast downlink per round — and each uplink is a 4P
             lossless delta: 4P + V*4P bytes (~2x at large V).
  delta_int8 same broadcast downlink; each uplink is blockwise int8
             codes + one f32 scale per 256 params: 4P + V*(P' + P'/64)
             bytes (P' = P padded to 256) — ~7.9x at V=1024 and rising
             with V toward the 4P/(P'*65/64) ~ 3.94x uplink-only ratio
             times the unicast-downlink savings.

Both the total (down+up) and the uplink-only ratios are reported; the
acceptance gate (>= 4x total at V >= 1024 for delta_int8) is asserted
here, as is the lossless tier's bitwise-identical aggregation.

  PYTHONPATH=src python benchmarks/comms.py [--smoke]

Writes benchmarks/results/BENCH_comms.json (CI uploads it as an
artifact; the committed copy at the repo root feeds the README table).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import jax.numpy as jnp
import numpy as np

from common import emit, save_json


def _fleet_cohort(m, seed=0):
    """m stacked per-vehicle trees (~1.9k params each — the wire cost is
    what scales here, so the trees stay allocator-friendly at V=10k)."""
    from repro.core.cohort import CohortBatch
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    trees = {"conv": jax.random.normal(ks[0], (m, 8, 3, 3)),
             "dense": jax.random.normal(ks[1], (m, 48, 32)),
             "head": jax.random.normal(ks[2], (m, 32, 8)),
             "bias": jax.random.normal(ks[3], (m, 48))}
    blur = jax.random.uniform(jax.random.fold_in(key, 9), (m,),
                              minval=10.0, maxval=20.0)
    return CohortBatch.from_stacked(trees, jnp.zeros((m,)), n=m, blur=blur)


def _time(fn, repeats, what):
    from repro.analysis.guards import assert_compile_bounds, track_compiles
    out = fn()                                            # warmup/compile
    jax.block_until_ready(jax.tree.leaves(out)[0])
    with track_compiles() as tracker:
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn()
            jax.block_until_ready(jax.tree.leaves(out)[0])
        dt = time.perf_counter() - t0
    assert_compile_bounds({"steady_state": tracker.backend_compiles},
                          {"steady_state": 0}, what=f"comms/{what}")
    return dt / repeats * 1e6, out


def _assert_bitwise(ref, got, label):
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise SystemExit(f"lossless codec changed the aggregation: "
                             f"{label}")


def round_bytes(codec_name, base, payload, V):
    """(downlink, uplink, total) bytes for one round's exchange."""
    from repro.comms.codecs import payload_nbytes, tree_nbytes
    model = tree_nbytes(base)
    up = payload_nbytes(payload)            # the whole stacked cohort
    if codec_name == "identity":
        down = V * model                    # per-vehicle unicast
    else:
        down = model                        # one broadcast of theta
    return down, up, down + up


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="single 1k-vehicle point, 1 repeat")
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--fleet", type=int, nargs="+",
                    default=[1024, 4096, 10240])
    args = ap.parse_args(argv)

    from repro.comms.codecs import (CODECS, comms_init_state,
                                    roundtrip_cohort, tree_nbytes)
    from repro.core.aggregation import AGGREGATORS
    from repro.core.state import FLConfig

    fleet = [1024] if args.smoke else args.fleet
    repeats = 1 if args.smoke else args.repeats
    results = {"config": {"fleet": fleet, "repeats": repeats,
                          "smoke": bool(args.smoke),
                          "backend": jax.default_backend()}}

    for V in fleet:
        c = _fleet_cohort(V)
        base = jax.tree.map(lambda x: x[0] * 0.5, c.trees)
        P = sum(int(l.size) for l in jax.tree.leaves(base))
        results["params_per_vehicle"] = P
        results["model_bytes"] = tree_nbytes(base)
        row = {}

        # plain full-tree aggregation: the latency baseline AND the
        # bitwise reference for the lossless tier. Stages are jitted —
        # in production the codec traces into the engine round body,
        # so eager dispatch overhead is not the thing to price
        cfg0 = FLConfig(aggregator="flsimco", vehicles_per_round=V)
        # analysis: allow=retrace-ctor -- one jit per fleet size by
        # design; _time pins steady_state compiles to 0 regardless
        agg0 = jax.jit(lambda c_: AGGREGATORS["flsimco"](c_, cfg0))
        us0, ref = _time(lambda: agg0(c), repeats, what=f"identity@V={V}")
        emit("comms/identity/agg", us0, f"V={V}")
        d, u, t = round_bytes("identity", base, c.trees, V)
        row["identity"] = {"latency_us": us0, "down_bytes": d,
                           "up_bytes": u, "total_bytes": t}

        for name in ("delta", "delta_int8"):
            cfg = FLConfig(aggregator="flsimco", vehicles_per_round=V,
                           codec=name)
            comms = comms_init_state(cfg, base)

            # analysis: allow=retrace-ctor -- one jit per (codec, V)
            # point by design; compile bound asserted in _time
            stage = jax.jit(lambda c_, b_, s_, cfg=cfg: AGGREGATORS[
                "flsimco"](roundtrip_cohort(cfg, c_, b_, s_)[0], cfg))
            us, got = _time(lambda: stage(c, base, comms), repeats,
                            what=f"{name}@V={V}")
            if CODECS[name].lossless:
                _assert_bitwise(ref, got, f"{name} @ V={V}")
            payload, _ = CODECS[name].encode(
                c.trees, base, None if comms is None else comms["ef"])
            d, u, t = round_bytes(name, base, payload, V)
            full = row["identity"]
            row[name] = {
                "latency_us": us, "down_bytes": d, "up_bytes": u,
                "total_bytes": t,
                "ratio_total": full["total_bytes"] / t,
                "ratio_uplink": full["up_bytes"] / u,
            }
            emit(f"comms/{name}/agg", us,
                 f"V={V};x{row[name]['ratio_total']:.2f}")

        gate = row["delta_int8"]["ratio_total"]
        if V >= 1024 and gate < 4.0:
            raise SystemExit(f"delta_int8 bytes/round reduction {gate:.2f}x "
                             f"< 4x at V={V}")
        results[f"v{V}"] = row
        sys.stdout.flush()

    save_json("BENCH_comms.json", results)
    return results


if __name__ == "__main__":
    main()
