"""Paper Fig. 4: FLSimCo vs FedCo accuracy, IID and Non-IID.

Claim under test (Sec. 5.2): FLSimCo beats FedCo at equal rounds on both
IID and Dirichlet(0.1) Non-IID splits (paper: +13.03% IID / +8.2%
Non-IID on CIFAR-10). Here the dataset is the synthetic 10-class
substitute (DESIGN.md deviation #1) so the *ordering* is the claim.

CI scale via --rounds/--vehicles; paper scale: 95 vehicles, 150 rounds.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import build_scenario, emit, probe_accuracy, save_json
from repro.core import scenario as scn


def run(iid: bool, aggregator: str, rounds: int, vehicles: int,
        per_round: int, batch: int, n_per_class: int, seed: int = 0):
    sc = build_scenario(vehicles, n_per_class, iid, alpha=0.1, seed=seed,
                        min_per_client=40, aggregator=aggregator,
                        vehicles_per_round=per_round, batch_size=batch,
                        rounds=rounds, queue_len=1024, lr=0.5)
    t0 = time.time()
    state, hist = scn.run(sc)
    dt = time.time() - t0
    x, y = sc.dataset
    acc = probe_accuracy(state.global_tree, x, y)
    return acc, [h["loss"] for h in hist], dt


def main(args=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--vehicles", type=int, default=10)
    ap.add_argument("--per-round", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--n-per-class", type=int, default=100)
    a = ap.parse_args(args)

    out = {}
    for iid in (True, False):
        tag = "iid" if iid else "noniid_d0.1"
        for agg in ("flsimco", "fedco"):
            t0 = time.time()
            acc, losses, dt = run(iid, agg, a.rounds, a.vehicles,
                                  a.per_round, a.batch, a.n_per_class)
            out[f"{tag}/{agg}"] = {"top1": acc, "losses": losses}
            emit(f"fig4/{tag}/{agg}", dt * 1e6 / max(a.rounds, 1),
                 f"top1={acc:.4f}")
    for tag in ("iid", "noniid_d0.1"):
        gain = out[f"{tag}/flsimco"]["top1"] - out[f"{tag}/fedco"]["top1"]
        emit(f"fig4/{tag}/flsimco_minus_fedco", 0.0, f"delta_top1={gain:+.4f}")
    save_json("fig4.json", out)
    return out


if __name__ == "__main__":
    main()
