"""Paper Fig. 5: vehicles-per-round and local-iteration count.

Claims under test: (i) fewer vehicles per round -> higher *early*
accuracy (diversity argument, Fig. 5a); (ii) 2 local iterations converge
faster / to lower loss than 1 (Fig. 5b, Non-IID).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import build_scenario, emit, probe_accuracy, save_json
from repro.core import scenario as scn


def run(per_round: int, local_iters: int, rounds: int, vehicles: int,
        batch: int, n_per_class: int):
    sc = build_scenario(vehicles, n_per_class, iid=False, alpha=0.1,
                        min_per_client=40, vehicles_per_round=per_round,
                        batch_size=batch, rounds=rounds,
                        local_iters=local_iters, lr=0.5)
    t0 = time.time()
    state, hist = scn.run(sc)
    dt = time.time() - t0
    x, y = sc.dataset
    early = probe_accuracy(state.global_tree, x, y)
    return early, [h["loss"] for h in hist], dt


def main(args=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--vehicles", type=int, default=12)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--n-per-class", type=int, default=100)
    a = ap.parse_args(args)

    out = {}
    for per_round, iters in ((3, 1), (6, 1), (3, 2)):
        acc, losses, dt = run(per_round, iters, a.rounds, a.vehicles,
                              a.batch, a.n_per_class)
        key = f"n{per_round}_it{iters}"
        out[key] = {"early_top1": acc, "losses": losses,
                    "final_loss": float(np.mean(losses[-2:]))}
        emit(f"fig5/{key}", dt * 1e6 / max(a.rounds, 1),
             f"early_top1={acc:.4f};final_loss={out[key]['final_loss']:.4f}")
    save_json("fig5.json", out)
    return out


if __name__ == "__main__":
    main()
