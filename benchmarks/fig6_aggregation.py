"""Paper Fig. 6: aggregation-scheme comparison under motion blur.

Claim under test: blur-weighted aggregation (FLSimCo) yields a more
stable loss curve than baseline1 (plain FedAvg over blurred models) and
baseline2 (discard models from vehicles over 100 km/h), measured by the
std of the loss-curve gradient (paper: 0.067 vs 0.23 / 0.10 — reductions
of 70.9% and 33%).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import build_scenario, emit, save_json
from repro.core import scenario as scn
from repro.core.federation import gradient_std


def run(aggregator: str, rounds: int, vehicles: int, per_round: int,
        batch: int, n_per_class: int, seed: int):
    sc = build_scenario(vehicles, n_per_class, iid=False, alpha=0.1,
                        min_per_client=40, seed=seed, aggregator=aggregator,
                        vehicles_per_round=per_round, batch_size=batch,
                        rounds=rounds, lr=0.5)
    _, hist = scn.run(sc)
    return [h["loss"] for h in hist]


def main(args=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--vehicles", type=int, default=10)
    ap.add_argument("--per-round", type=int, default=4)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--n-per-class", type=int, default=80)
    ap.add_argument("--repeats", type=int, default=1)
    a = ap.parse_args(args)

    out = {}
    for agg, label in (("flsimco", "flsimco"), ("fedavg", "baseline1"),
                       ("discard", "baseline2")):
        stds, curves = [], []
        t0 = time.time()
        for rep in range(a.repeats):
            losses = run(agg, a.rounds, a.vehicles, a.per_round, a.batch,
                         a.n_per_class, seed=rep)
            stds.append(gradient_std(losses))
            curves.append(losses)
        dt = time.time() - t0
        out[label] = {"grad_std": float(np.mean(stds)), "losses": curves[0]}
        emit(f"fig6/{label}", dt * 1e6 / max(a.rounds * a.repeats, 1),
             f"grad_std={np.mean(stds):.4f}")
    if out["baseline1"]["grad_std"] > 0:
        red1 = 1 - out["flsimco"]["grad_std"] / out["baseline1"]["grad_std"]
        red2 = 1 - out["flsimco"]["grad_std"] / max(out["baseline2"]["grad_std"], 1e-9)
        emit("fig6/grad_std_reduction_vs_b1", 0.0, f"{red1:+.1%}")
        emit("fig6/grad_std_reduction_vs_b2", 0.0, f"{red2:+.1%}")
    save_json("fig6.json", out)
    return out


if __name__ == "__main__":
    main()
