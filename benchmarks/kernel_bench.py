"""Kernel microbenchmarks: fused Pallas vs unfused jnp reference.

On this CPU container Pallas runs in interpret mode (python-speed), so
wall-clock favors the jnp path; the meaningful CPU-side numbers are the
jnp-reference timings and the HBM-traffic model. The derived column
reports the modeled HBM bytes saved by fusion on TPU (the quantity the
kernels exist for).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, save_json


def _time(fn, *args, iters=5):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def main(args=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args(args)
    out = {}
    key = jax.random.PRNGKey(0)

    # dt_loss: unfused writes sim (M,M) f32 3-4x; fused writes only (M,) x4
    M, D = (256, 128) if a.quick else (512, 128)
    q = jax.random.normal(key, (M, D))
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    k = jax.random.normal(jax.random.fold_in(key, 1), (M, D))
    k = k / jnp.linalg.norm(k, axis=-1, keepdims=True)
    from repro.core.dt_loss import dt_loss_matrix
    t_ref = _time(jax.jit(lambda q, k: dt_loss_matrix(q, k, 0.1, 1.0)), q, k)
    saved = 3 * M * M * 4  # sim materializations avoided
    emit("kernel/dt_loss/jnp_ref", t_ref, f"M={M};D={D}")
    out["dt_loss"] = {"ref_us": t_ref, "hbm_saved_bytes": saved}
    emit("kernel/dt_loss/fused_hbm_saved", 0.0, f"{saved}B")

    # wagg: N reads fused into 1 pass
    N, P = 5, 1 << (18 if a.quick else 20)
    x = jax.random.normal(key, (N, P))
    w = jnp.full((N,), 1 / N)
    from repro.kernels.ref import wagg_ref
    t_ref = _time(jax.jit(wagg_ref), x, w)
    emit("kernel/wagg/jnp_ref", t_ref, f"N={N};P={P}")
    out["wagg"] = {"ref_us": t_ref,
                   "hbm_saved_bytes": (N - 1) * P * 4}
    emit("kernel/wagg/fused_hbm_saved", 0.0, f"{(N-1)*P*4}B")

    # rwkv6: chunked (MXU matmuls) vs token-sequential scan
    BH, S, Dh = (8, 256, 64) if a.quick else (16, 1024, 64)
    ks = jax.random.split(key, 5)
    r, kk, v = (jax.random.normal(ks[i], (BH, S, Dh)) * 0.5 for i in range(3))
    logw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (BH, S, Dh))), -4, -1e-4)
    u = jax.random.normal(ks[4], (Dh,)) * 0.3
    from repro.kernels.ref import rwkv6_ref
    t_seq = _time(jax.jit(rwkv6_ref), r, kk, v, logw, u)
    emit("kernel/rwkv6/sequential_ref", t_seq, f"BH={BH};S={S}")
    out["rwkv6"] = {"seq_us": t_seq,
                    "matmul_fraction": "chunked form is MXU-bound"}
    save_json("kernel_bench.json", out)
    return out


if __name__ == "__main__":
    main()
