"""Multi-RSU scaling benchmark: round latency over (vehicles x RSUs).

Sweeps the topology layer end to end — per-RSU vmapped cohorts, two-level
Eq.-11 aggregation, and (for the handover grid) position advancement and
stale-upload reweighting — and reports us/round after a warmup round.
Also times the host aggregation step alone under both weighted-sum
backends (tree-map vs the fused wagg kernel in interpret mode) so the
crossover is visible off-TPU.

  PYTHONPATH=src python benchmarks/multi_rsu.py [--rounds 3]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import numpy as np

from common import build_world, emit, save_json


def time_rounds(scenario, n_rounds, parallel=True):
    from repro.core.scenario import run_round
    state = scenario.init_state()
    state, _ = run_round(state, scenario, parallel=parallel)  # warmup
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        state, _ = run_round(state, scenario, parallel=parallel)
    return (time.perf_counter() - t0) / n_rounds * 1e6


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--batch", type=int, default=32)
    # CPU-friendly default grid; widen on real hardware, e.g.
    #   --vehicles 4 8 16 --rsus 1 2 4 8
    ap.add_argument("--vehicles", type=int, nargs="+", default=[4])
    ap.add_argument("--rsus", type=int, nargs="+", default=[1, 2, 4])
    args = ap.parse_args()
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")

    from repro.core import aggregation as agg
    from repro.core.scenario import Scenario
    from repro.core.topology import HandoverMultiRSU, MultiRSU, SingleRSU

    results = {}
    x, y, parts, tree = build_world(n_vehicles=24, n_per_class=40,
                                    iid=True, alpha=0.0)
    data = [x[p] for p in parts]

    for n_vehicles in args.vehicles:
        for n_rsus in args.rsus:
            if n_rsus > n_vehicles:
                continue
            base = dict(data=data, global_tree=tree, n_vehicles=24,
                        vehicles_per_round=n_vehicles,
                        batch_size=args.batch, rounds=args.rounds + 1,
                        local_iters=1, seed=0)
            sc = Scenario(topology=MultiRSU(n_rsus=n_rsus), **base)
            us = time_rounds(sc, args.rounds)
            emit("topology/multi_rsu/round", us,
                 f"V={n_vehicles};R={n_rsus}")
            sys.stdout.flush()
            results[f"multi_v{n_vehicles}_r{n_rsus}"] = us

            topo = HandoverMultiRSU(n_rsus=n_rsus, rsu_range=500.0,
                                    round_duration=30.0, sync_every=2)
            sc = Scenario(topology=topo, **base)
            # vmapped bucketed path (the default): cohort sizes vary per
            # round but padding to power-of-two buckets bounds compiles.
            # Pre-warm every bucket so no compile lands in the timed
            # window — benchmarks/round_engine.py isolates list vs
            # CohortBatch and prices the compiles themselves
            from round_engine import _warm_buckets
            _warm_buckets(sc)
            us = time_rounds(sc, args.rounds, parallel=True)
            emit("topology/handover/round", us,
                 f"V={n_vehicles};R={n_rsus}")
            sys.stdout.flush()
            results[f"handover_v{n_vehicles}_r{n_rsus}"] = us

    # aggregation-only: tree-map vs fused kernel (interpret) on the real tree
    from repro.core.aggregation import aggregate_flsimco
    trees = [jax.tree.map(lambda l, i=i: l + i, tree) for i in range(8)]
    blur = np.linspace(10.0, 24.0, 8)
    for backend in ("tree", "interpret"):
        with agg.wagg_backend(backend):
            out = aggregate_flsimco(trees, blur)     # warmup
            jax.block_until_ready(jax.tree.leaves(out)[0])
            t0 = time.perf_counter()
            for _ in range(3):
                out = aggregate_flsimco(trees, blur)
                jax.block_until_ready(jax.tree.leaves(out)[0])
            us = (time.perf_counter() - t0) / 3 * 1e6
        emit(f"topology/agg_{backend}/resnet18_n8", us, "")
        results[f"agg_{backend}"] = us

    save_json("multi_rsu.json", results)


if __name__ == "__main__":
    main()
