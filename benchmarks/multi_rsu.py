"""Multi-RSU scaling benchmark: round latency over (vehicles x RSUs),
plus the fleet-scale SHARDED aggregation sweep.

Default mode sweeps the topology layer end to end — per-RSU vmapped
cohorts, two-level Eq.-11 aggregation, and (for the handover grid)
position advancement and stale-upload reweighting — and reports us/round
after a warmup round. Also times the host aggregation step alone under
both weighted-sum backends (tree-map vs the fused wagg kernel in
interpret mode) so the crossover is visible off-TPU.

`--sharded` switches to the fleet-scale mode: cohorts of 1k-10k
vehicles/round (small synthetic trees — client training at that scale is
not a CPU benchmark, aggregation is) pushed through `sharded_aggregate`
(gather and split reductions) and `sharded_hierarchical` on the
("pod","data") mesh, against the single-device dispatch as both the
baseline timing AND a bitwise-equality check. When fewer than 8 devices
are visible the flag forces 8 host devices by setting XLA_FLAGS before
jax is imported — this is why argv is inspected at module scope.

  PYTHONPATH=src python benchmarks/multi_rsu.py [--rounds 3]
  PYTHONPATH=src python benchmarks/multi_rsu.py --sharded [--smoke]

Writes benchmarks/results/BENCH_multi_rsu.json (uploaded as a CI
artifact by the multidevice job; --smoke shrinks the sweep to one
1k-vehicle point so the job stays in minutes).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# Forcing host devices only works before jax initializes — peek at argv
# prior to the jax import rather than after argparse runs.
if ("--sharded" in sys.argv or "--smoke" in sys.argv) and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8"
                               ).strip()

import jax
import jax.numpy as jnp
import numpy as np

from common import build_world, emit, save_json


def time_rounds(scenario, n_rounds, parallel=True):
    from repro.core.scenario import run_round
    state = scenario.init_state()
    state, _ = run_round(state, scenario, parallel=parallel)  # warmup
    t0 = time.perf_counter()
    for _ in range(n_rounds):
        state, _ = run_round(state, scenario, parallel=parallel)
    return (time.perf_counter() - t0) / n_rounds * 1e6


def _time_agg(fn, repeats, what="agg"):
    from repro.analysis.guards import assert_compile_bounds, track_compiles

    out = fn()                                            # warmup/compile
    jax.block_until_ready(jax.tree.leaves(out)[0])
    # the timed window is steady state by contract: the warmup call above
    # compiled everything, so zero backend compiles may land inside it —
    # pinned through the shared guards tracker, same rail as the engine
    with track_compiles() as tracker:
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn()
            jax.block_until_ready(jax.tree.leaves(out)[0])
        dt = time.perf_counter() - t0
    assert_compile_bounds({"steady_state": tracker.backend_compiles},
                          {"steady_state": 0}, what=f"multi_rsu/{what}")
    return dt / repeats * 1e6, out


def _fleet_cohort(m, seed=0):
    """m stacked per-vehicle trees, small on purpose: ~2.4k params per
    vehicle keeps a 10k-vehicle cohort under 100 MB so the benchmark
    prices the reduction, not the allocator."""
    from repro.core.cohort import CohortBatch
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    trees = {"conv": jax.random.normal(ks[0], (m, 8, 3, 3)),
             "dense": jax.random.normal(ks[1], (m, 48, 32)),
             "head": jax.random.normal(ks[2], (m, 32, 8)),
             "bias": jax.random.normal(ks[3], (m, 48))}
    blur = jax.random.uniform(jax.random.fold_in(key, 9), (m,),
                              minval=10.0, maxval=20.0)
    return CohortBatch.from_stacked(trees, jnp.zeros((m,)), n=m, blur=blur)


def _assert_bitwise(ref, got, label):
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise SystemExit(f"sharded result diverged from the "
                             f"single-device reference: {label}")


def run_sharded(args, results):
    from repro.core.aggregation import AGGREGATORS
    from repro.core.hierarchical import (aggregate_hierarchical,
                                         sharded_aggregate,
                                         sharded_hierarchical)
    from repro.core.state import FLConfig
    from repro.launch.mesh import cohort_axis_divisor, cohort_mesh

    n_dev = jax.device_count()
    if n_dev < 2:
        raise SystemExit(f"--sharded needs >= 2 devices, have {n_dev}; "
                         "the module-scope XLA_FLAGS forcing should have "
                         "provided 8 — is XLA_FLAGS already set?")
    n_rsus = 2
    fleet = [1024] if args.smoke else args.fleet
    repeats = 1 if args.smoke else args.rounds
    results["config"] = {"devices": n_dev, "n_rsus": n_rsus,
                         "fleet": fleet, "repeats": repeats,
                         "smoke": bool(args.smoke)}
    cfg = FLConfig(aggregator="flsimco")

    for m in fleet:
        mesh = cohort_mesh(n_rsus, cohort_axis_divisor(m // n_rsus, n_rsus))
        c = _fleet_cohort(m)
        tag = f"V={m};mesh={dict(mesh.shape)}"

        us_host, ref = _time_agg(
            lambda: AGGREGATORS["flsimco"](c, cfg), repeats,
            what=f"host_reference/agg@V={m}")
        emit("sharded/host_reference/agg", us_host, tag)
        results[f"host_v{m}"] = us_host

        for reduction in ("gather", "split"):
            us, got = _time_agg(
                lambda r=reduction: sharded_aggregate(c, cfg, mesh,
                                                      reduction=r), repeats,
                what=f"{reduction}/agg@V={m}")
            _assert_bitwise(ref, got, f"{reduction} @ V={m}")
            emit(f"sharded/{reduction}/agg", us, tag)
            results[f"{reduction}_v{m}"] = us

        # two-level Eq.-11 over the same fleet, m/2 vehicles per RSU
        from repro.core.cohort import CohortBatch
        blur = c.blur
        cohorts = [CohortBatch.from_stacked(
            jax.tree.map(lambda x, r=r: x[r * (m // 2):(r + 1) * (m // 2)],
                         c.trees),
            jnp.zeros((m // 2,)),
            blur=blur[r * (m // 2):(r + 1) * (m // 2)])
            for r in range(n_rsus)]
        us_h, ref_h = _time_agg(
            lambda: aggregate_hierarchical(cohorts), repeats,
            what=f"host_reference/hier@V={m}")
        emit("sharded/host_reference/hier", us_h, tag)
        results[f"hier_host_v{m}"] = us_h
        us_s, got_h = _time_agg(
            lambda: sharded_hierarchical(c.trees, blur, mesh, n_rsus),
            repeats, what=f"mesh_exact/hier@V={m}")
        _assert_bitwise(ref_h, got_h, f"hierarchical @ V={m}")
        emit("sharded/mesh_exact/hier", us_s, tag)
        results[f"hier_mesh_v{m}"] = us_s
        sys.stdout.flush()

    return results


def run_topology(args, results):
    from repro.core import aggregation as agg
    from repro.core.scenario import Scenario
    from repro.core.topology import HandoverMultiRSU, MultiRSU

    x, y, parts, tree = build_world(n_vehicles=24, n_per_class=40,
                                    iid=True, alpha=0.0)
    data = [x[p] for p in parts]

    for n_vehicles in args.vehicles:
        for n_rsus in args.rsus:
            if n_rsus > n_vehicles:
                continue
            base = dict(data=data, global_tree=tree, n_vehicles=24,
                        vehicles_per_round=n_vehicles,
                        batch_size=args.batch, rounds=args.rounds + 1,
                        local_iters=1, seed=0)
            sc = Scenario(topology=MultiRSU(n_rsus=n_rsus), **base)
            us = time_rounds(sc, args.rounds)
            emit("topology/multi_rsu/round", us,
                 f"V={n_vehicles};R={n_rsus}")
            sys.stdout.flush()
            results[f"multi_v{n_vehicles}_r{n_rsus}"] = us

            topo = HandoverMultiRSU(n_rsus=n_rsus, rsu_range=500.0,
                                    round_duration=30.0, sync_every=2)
            sc = Scenario(topology=topo, **base)
            # vmapped bucketed path (the default): cohort sizes vary per
            # round but padding to power-of-two buckets bounds compiles.
            # Pre-warm every bucket so no compile lands in the timed
            # window — benchmarks/round_engine.py isolates list vs
            # CohortBatch and prices the compiles themselves
            from round_engine import _warm_buckets
            _warm_buckets(sc)
            us = time_rounds(sc, args.rounds, parallel=True)
            emit("topology/handover/round", us,
                 f"V={n_vehicles};R={n_rsus}")
            sys.stdout.flush()
            results[f"handover_v{n_vehicles}_r{n_rsus}"] = us

    # aggregation-only: tree-map vs fused kernel (interpret) on the real tree
    from repro.core.aggregation import aggregate_flsimco
    trees = [jax.tree.map(lambda l, i=i: l + i, tree) for i in range(8)]
    blur = np.linspace(10.0, 24.0, 8)
    for backend in ("tree", "interpret"):
        with agg.wagg_backend(backend):
            out = aggregate_flsimco(trees, blur)     # warmup
            jax.block_until_ready(jax.tree.leaves(out)[0])
            t0 = time.perf_counter()
            for _ in range(3):
                out = aggregate_flsimco(trees, blur)
                jax.block_until_ready(jax.tree.leaves(out)[0])
            us = (time.perf_counter() - t0) / 3 * 1e6
        emit(f"topology/agg_{backend}/resnet18_n8", us, "")
        results[f"agg_{backend}"] = us
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--batch", type=int, default=32)
    # CPU-friendly default grid; widen on real hardware, e.g.
    #   --vehicles 4 8 16 --rsus 1 2 4 8
    ap.add_argument("--vehicles", type=int, nargs="+", default=[4])
    ap.add_argument("--rsus", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--sharded", action="store_true",
                    help="fleet-scale sharded aggregation sweep")
    ap.add_argument("--smoke", action="store_true",
                    help="imply --sharded; single 1k point, 1 repeat")
    ap.add_argument("--fleet", type=int, nargs="+",
                    default=[1024, 4096, 10240],
                    help="vehicles/round for the sharded sweep")
    args = ap.parse_args()
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")

    results = {}
    if args.sharded or args.smoke:
        run_sharded(args, results)
    else:
        run_topology(args, results)
    save_json("BENCH_multi_rsu.json", results)


if __name__ == "__main__":
    main()
