"""Roofline analysis from the dry-run artifacts (deliverable (g)).

Reads benchmarks/results/dryrun.json (produced by repro.launch.dryrun) and
derives, per (arch x shape x mesh):

    compute_term    = HLO_FLOPs_per_device / peak_FLOPs
    memory_term     = HLO_bytes_per_device / HBM_bw
    collective_term = collective_bytes_per_device / link_bw

dominant bottleneck = argmax of the three. Also reports MODEL_FLOPS =
6*N*D (6*N_active*D for MoE) and its ratio to compiled FLOPs (remat /
redundancy waste detector).

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI
per link (3 links/chip usable -> we charge the busiest-link model:
collective bytes / link_bw).
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import RESULTS_DIR, emit, save_json
from repro.configs.base import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    n = cfg.n_active_params() if cfg.is_moe else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(rec: dict, key: str) -> dict:
    arch, shape, mesh_name = rec["arch"], rec["shape"], rec["mesh"]
    chips = 512 if mesh_name == "2x16x16" else 256
    cal = rec.get("calibrated")
    if cal:  # depth-extrapolated (scan bodies counted per layer)
        flops_dev, bytes_dev, coll_dev = cal["flops"], cal["bytes"], cal["coll"]
    else:
        flops_dev = rec["cost"].get("flops", 0.0)
        bytes_dev = rec["cost"].get("bytes accessed", 0.0)
        coll_dev = sum(v for k, v in rec["collectives"].items()
                       if not k.startswith("count_"))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape)
    mf_dev = mf / chips
    useful = mf_dev / flops_dev if flops_dev else 0.0
    bound = max(terms.values())
    mfu_bound = (mf_dev / PEAK_FLOPS) / bound if bound else 0.0
    return {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf_dev,
        "useful_flops_ratio": useful,
        "roofline_mfu_bound": mfu_bound,
        "peak_bytes": rec.get("memory", {}).get("peak_bytes"),
        "n_micro": rec.get("n_micro"),
        "calibrated": bool(cal),
    }


def main(args=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default=os.path.join(RESULTS_DIR, "dryrun.json"))
    ap.add_argument("--markdown", action="store_true")
    a = ap.parse_args(args)
    if not os.path.exists(a.dryrun):
        emit("roofline/missing", 0.0, "run repro.launch.dryrun first")
        return {}
    with open(a.dryrun) as f:
        recs = json.load(f)
    rows = []
    for key, rec in sorted(recs.items()):
        if not rec.get("ok"):
            continue
        r = analyze(rec, key)
        rows.append(r)
        emit(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
             max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
             f"dom={r['dominant']};useful={r['useful_flops_ratio']:.2f};"
             f"mfu_bound={r['roofline_mfu_bound']:.3f}")
    save_json("roofline.json", rows)
    if a.markdown:
        print(markdown_table(rows))
    return rows


def markdown_table(rows):
    hdr = ("| arch | shape | mesh | compute (s) | memory (s) | collective (s)"
           " | dominant | useful | MFU bound |\n|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} | {r['roofline_mfu_bound']:.3f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    main()
