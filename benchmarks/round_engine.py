"""Round-engine benchmark: list path vs device-resident CohortBatch path.

Times rounds/sec and counts cohort-step compiles for the client-boundary
variants across the three topologies:

  list    parallel=False — the sequential per-client reference: one jit
          dispatch + one `float(loss)` sync per client (the path
          handover was stuck on before bucketing).
  naive   (handover only) parallel=True with bucketed=False — the
          vmapped step at each group's EXACT size. Vehicle motion keeps
          producing new cohort sizes, so this path keeps paying fresh
          XLA compiles; its timed window deliberately includes them
          because that IS its steady state. This is the failure mode
          that forced handover onto the sequential path.
  cohort  parallel=True — the stacked `CohortBatch` engine: per-group
          vmapped dispatch padded to power-of-two buckets, masked-weight
          aggregation on the stacked leaves, one device fetch per round.
          All (<= ceil(log2(V)) + 1) bucket sizes are pre-warmed, so the
          timed window is steady state — bounded compiles are the point.

On top of the per-round paths, the CAMPAIGN engine (core/engine.py) is
timed end to end for `--campaign-topos` (default single,handover):

  jit_round  run_campaign(mode="jit") — one fused round program, python
             loop, once-per-chunk history fetch (the CPU fast path).
  scan       run_campaign(mode="scan") — lax.scan chunks (the
             accelerator path; on CPU the scan's while loop pessimizes
             the convolutions, so this entry is EXPECTED to lose here).

Compile counts come from the vmapped step's jit cache
(`clients.cohort_step_cache_size`) and, for the campaign entries, from
`engine.compile_counts`. Note for CPU runs: XLA-CPU gains little from
batching an already compute-bound cohort (the cores saturate either
way), so cohort-vs-list hovers near 1x for single/multi and the
handover bucket padding (up to ~1.5x extra client-slots) is paid in
full — while XLA-CPU recompiles of the small step are cheap enough
that the naive path partially amortizes them. The same asymmetry caps
the campaign engine on CPU: jit_round lands ~1.2-1.5x over the eager
cohort path (the fused body removes per-round dispatch + host syncs,
but the vmapped conv gradients dominate), and the >= 2x target — like
the cohort-path target below — is an accelerator-backend claim, where
fusing K rounds into one dispatch amortizes launch overhead that CPU
never pays. What this bench pins on EVERY backend is the compile
BOUND: the cohort path never exceeds ceil(log2(vehicles_per_round))+1
cohort-step compiles per topology, the campaign engine never exceeds
ONE jit_round program and one scan program per distinct chunk length
(<= 2 for a fixed cadence) — handover regrouping is data, not shape —
while the naive path grows without bound.

  PYTHONPATH=src python benchmarks/round_engine.py [--rounds 3]

Writes benchmarks/results/BENCH_round_engine.json (uploaded as a CI
artifact by the benchmark smoke step).
"""
import argparse
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import jax.numpy as jnp
import numpy as np

from common import build_world, emit, save_json


def _warm_sizes(scenario):
    """The cohort-step sizes this topology actually compiles: the fixed
    cohort (single), the round-robin group sizes (multi), or the
    power-of-two buckets (handover). The naive unbucketed handover has
    no warmable set — new sizes keep appearing; that IS its cost."""
    from repro.core.cohort import bucket_size
    from repro.core.topology import HandoverMultiRSU, MultiRSU

    topo, V = scenario.topology, scenario.cfg.vehicles_per_round
    if isinstance(topo, HandoverMultiRSU):
        if not topo.bucketed:
            return []
        return sorted({bucket_size(s) for s in range(1, V + 1)})
    if isinstance(topo, MultiRSU):
        counts = np.bincount(np.arange(V) % topo.n_rsus)
        return sorted({int(c) for c in counts if c})
    return [V]


def _warm_buckets(scenario):
    """Pre-compile every cohort-step size the run can hit, so the timed
    window measures steady-state rounds/sec (the bounded compile set is
    the point of bucketing — pay it once, up front). Uses the real
    scheduler's lr so the warm entries are the ones the rounds reuse
    (a python-float lr is a different jit cache key)."""
    from repro.core.clients import CLIENT_UPDATES

    cfg = scenario.cfg
    client = CLIENT_UPDATES[cfg.client]
    tree = scenario.init_tree()
    lr = scenario.lr_fn(0)
    # image shape/dtype from the real dataset — a hardcoded shape would
    # silently warm the wrong jit entries and let compiles leak into the
    # timed window
    sample = np.asarray(scenario.data[0][:1])
    for m in _warm_sizes(scenario):
        images = jnp.zeros((1, cfg.batch_size) + sample.shape[1:],
                           sample.dtype)
        keys = [jax.random.PRNGKey(0)]
        cohort, _ = client.run_cohort(cfg, tree, None, images, keys, lr,
                                      parallel=True, pad_to=m)
        jax.block_until_ready(cohort.losses)


def time_path(scenario, rounds: int, parallel: bool, warm: bool):
    """(us_per_round, rounds_per_sec, cohort-step compile count)."""
    from repro.core.clients import (cohort_step_cache_size,
                                    reset_cohort_step_caches)
    from repro.core.scenario import run_round

    reset_cohort_step_caches()
    if warm:
        _warm_buckets(scenario)
    state = scenario.init_state()
    state, _ = run_round(state, scenario, parallel=parallel)   # engine warmup
    t0 = time.perf_counter()
    for _ in range(rounds):
        state, _ = run_round(state, scenario, parallel=parallel)
    dt = (time.perf_counter() - t0) / rounds
    return dt * 1e6, 1.0 / dt, cohort_step_cache_size(scenario.cfg)


def time_campaign(scenario, rounds: int, mode: str):
    """(us_per_round, rounds_per_sec) for the compiled campaign engine,
    steady state: the first call compiles + warms, the timed call replays
    the cached program(s)."""
    from repro.core.engine import run_campaign

    run_campaign(scenario, rounds=1, mode=mode)           # compile + warm
    t0 = time.perf_counter()
    state, _ = run_campaign(scenario, rounds=rounds, mode=mode)
    jax.block_until_ready(state.global_tree)
    dt = (time.perf_counter() - t0) / rounds
    return dt * 1e6, 1.0 / dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--vehicles", type=int, default=8,
                    help="vehicles_per_round (acceptance target: >= 8)")
    ap.add_argument("--rsus", type=int, default=2)
    ap.add_argument("--skip-naive", action="store_true",
                    help="skip the recompiling naive handover path "
                         "(it pays multi-minute XLA compiles by design)")
    ap.add_argument("--campaign-topos", default="single,handover",
                    help="comma list of topologies to run the campaign "
                         "engine on (empty string skips it; default "
                         "keeps CI compile cost bounded)")
    args = ap.parse_args()
    if args.rounds < 1:
        ap.error("--rounds must be >= 1")

    from repro.core.scenario import Scenario
    from repro.core.topology import HandoverMultiRSU, MultiRSU, SingleRSU

    V = args.vehicles
    compile_bound = int(math.ceil(math.log2(V))) + 1
    # fleet must exceed the per-round cohort (sampling is replace=False)
    n_fleet = max(24, 2 * V)
    x, y, parts, tree = build_world(n_vehicles=n_fleet, n_per_class=40,
                                    iid=True, alpha=0.0)
    base = dict(data=[x[p] for p in parts], global_tree=tree,
                n_vehicles=n_fleet,
                vehicles_per_round=V, batch_size=args.batch,
                rounds=args.rounds + 1, local_iters=1, seed=0)
    handover_kw = dict(n_rsus=args.rsus, rsu_range=500.0,
                       round_duration=30.0, sync_every=2)
    topologies = {
        "single": SingleRSU(),
        "multi": MultiRSU(n_rsus=args.rsus),
        "handover": HandoverMultiRSU(**handover_kw),
    }

    campaign_topos = {t for t in args.campaign_topos.split(",") if t}
    unknown = campaign_topos - set(topologies)
    if unknown:
        ap.error(f"--campaign-topos: unknown topologies {sorted(unknown)}")

    results = {"config": {"vehicles_per_round": V, "n_rsus": args.rsus,
                          "batch_size": args.batch, "rounds": args.rounds,
                          "backend": jax.default_backend(),
                          "compile_bound": compile_bound,
                          "campaign_topos": sorted(campaign_topos)}}
    for name, topo in topologies.items():
        sc = Scenario(topology=topo, **base)
        paths = [("list", sc, False, False), ("cohort", sc, True, True)]
        if name == "handover" and not args.skip_naive:
            naive_sc = Scenario(
                topology=HandoverMultiRSU(bucketed=False, **handover_kw),
                **base)
            paths.insert(1, ("naive", naive_sc, True, False))
        entry = {}
        for path, path_sc, parallel, warm in paths:
            us, rps, compiles = time_path(path_sc, args.rounds, parallel,
                                          warm)
            entry[path] = {"us_per_round": us, "rounds_per_sec": rps,
                           "cohort_step_compiles": compiles}
            emit(f"round_engine/{name}/{path}", us,
                 f"V={V};R={args.rsus};compiles={compiles}")
            sys.stdout.flush()
        entry["speedup_vs_list"] = (entry["list"]["us_per_round"]
                                    / entry["cohort"]["us_per_round"])
        if "naive" in entry:
            entry["speedup_vs_naive"] = (entry["naive"]["us_per_round"]
                                         / entry["cohort"]["us_per_round"])
        entry["within_compile_bound"] = \
            entry["cohort"]["cohort_step_compiles"] <= compile_bound
        if name in campaign_topos:
            from repro.core.engine import compile_counts, reset_engine_caches
            reset_engine_caches()
            for mode in ("jit", "scan"):
                us, rps = time_campaign(sc, args.rounds, mode)
                key = "jit_round" if mode == "jit" else "scan"
                entry[key] = {"us_per_round": us, "rounds_per_sec": rps}
                emit(f"round_engine/{name}/{key}", us, f"V={V};R={args.rsus}")
                sys.stdout.flush()
            counts = compile_counts(sc)
            # the campaign contract (jit_round <= 1, scan <= 2) lives in
            # analysis.guards.ENGINE_COMPILE_BOUNDS — one home, shared
            # with the engine tests
            from repro.analysis.guards import assert_compile_bounds
            assert_compile_bounds(counts, what=f"round_engine/{name}")
            entry["engine_compiles"] = counts
            entry["engine_within_compile_bound"] = True
            entry["speedup_jit_vs_cohort"] = (
                entry["cohort"]["us_per_round"]
                / entry["jit_round"]["us_per_round"])
            emit(f"round_engine/{name}/speedup_jit_vs_cohort",
                 entry["speedup_jit_vs_cohort"], "")
        results[name] = entry
        emit(f"round_engine/{name}/speedup_vs_list",
             entry["speedup_vs_list"], "")
        sys.stdout.flush()

    save_json("BENCH_round_engine.json", results)
    h = results["handover"]
    summary = [f"vs list {h['speedup_vs_list']:.2f}x"]
    if "speedup_vs_naive" in h:
        summary.append(f"vs naive(recompiling) "
                       f"{h['speedup_vs_naive']:.2f}x (target >= 2x)")
    print(f"# handover cohort-path speedup: {', '.join(summary)}; "
          f"compiles within bound "
          f"(<= {compile_bound}): "
          f"{all(results[t]['within_compile_bound'] for t in topologies)}")
    for t in sorted(campaign_topos):
        e = results[t]
        print(f"# {t} campaign engine: jit_round "
              f"{e['speedup_jit_vs_cohort']:.2f}x vs cohort path "
              f"(>= 2x is an accelerator-backend claim; CPU saturates on "
              f"the conv gradients), compiles "
              f"jit={e['engine_compiles']['jit_round']} "
              f"scan={e['engine_compiles']['scan']} (bounds 1/2)")


if __name__ == "__main__":
    main()
