"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per the harness contract.

  fig4  — FLSimCo vs FedCo top-1 (IID / Non-IID)         [paper Fig. 4]
  fig5  — vehicles-per-round & local iterations          [paper Fig. 5]
  fig6  — aggregation schemes, loss-gradient std         [paper Fig. 6]
  kernels — Pallas kernel microbench + fusion model
  comms — codec bytes/round + latency at fleet scale (BENCH_comms.json)
  serve — RSU serving throughput + fetch latency (BENCH_serve.json)
  roofline — per (arch x shape x mesh) roofline terms from the dry-run

Env knobs: BENCH_SCALE=ci|paper (default ci — minutes, not hours).
"""
from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    scale = os.environ.get("BENCH_SCALE", "ci")
    failures = []

    def run(name, fn):
        try:
            fn()
        except Exception as e:
            failures.append((name, e))
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc()

    from benchmarks import (beyond_weighting, comms, fig4_flsimco_vs_fedco,
                            fig5_cohort_size, fig6_aggregation, kernel_bench,
                            roofline, serve)

    if scale == "paper":
        run("fig4", lambda: fig4_flsimco_vs_fedco.main(
            ["--rounds", "150", "--vehicles", "95", "--per-round", "5",
             "--batch", "512", "--n-per-class", "5000"]))
        run("fig5", lambda: fig5_cohort_size.main(
            ["--rounds", "150", "--vehicles", "95", "--batch", "512",
             "--n-per-class", "5000"]))
        run("fig6", lambda: fig6_aggregation.main(
            ["--rounds", "150", "--vehicles", "95", "--per-round", "5",
             "--batch", "512", "--n-per-class", "5000", "--repeats", "3"]))
    else:
        run("fig4", lambda: fig4_flsimco_vs_fedco.main(
            ["--rounds", "4", "--vehicles", "8", "--per-round", "3",
             "--batch", "48", "--n-per-class", "60"]))
        run("fig5", lambda: fig5_cohort_size.main(
            ["--rounds", "3", "--vehicles", "9", "--batch", "48",
             "--n-per-class", "60"]))
        run("fig6", lambda: fig6_aggregation.main(
            ["--rounds", "4", "--vehicles", "8", "--per-round", "3",
             "--batch", "48", "--n-per-class", "60"]))
    if scale == "paper":
        run("beyond_weighting", lambda: beyond_weighting.main(
            ["--rounds", "150", "--vehicles", "95", "--per-round", "5",
             "--batch", "512", "--n-per-class", "5000"]))
    else:
        run("beyond_weighting", lambda: beyond_weighting.main(
            ["--rounds", "3", "--vehicles", "6", "--per-round", "3",
             "--batch", "32", "--n-per-class", "50"]))
    run("kernels", lambda: kernel_bench.main(["--quick"] if scale == "ci"
                                             else []))
    run("comms", lambda: comms.main(["--smoke"] if scale == "ci" else []))
    run("serve", lambda: serve.main(["--smoke"] if scale == "ci" else []))
    run("roofline", lambda: roofline.main([]))

    if failures:
        raise SystemExit(f"{len(failures)} benchmark(s) failed: "
                         f"{[n for n, _ in failures]}")


if __name__ == '__main__':
    main()
