"""RSU serving benchmark: models served/sec and fetch latency at fleet scale.

The measured headline for the async serving tier (src/repro/serve/):
publish a short campaign's worth of snapshots into a `ModelStore`, then
hammer an `RSUServer` with 1k-100k simulated vehicle fetches from
client threads — a realistic lag mix (most vehicles one round behind,
some two, a few ancient enough to hit the full-tree staleness
fallback) — and report models served/sec plus p50/p99 fetch latency
per fleet size.

In-bench gates (each raises SystemExit on failure):

  parity      replies on the delta-chain, multi-hop, and full-fallback
              paths all decode BITWISE equal to the published
              `FLState` model tree for the reply's round — the serving
              path never forks the fleet;
  accounting  submitted == served + shed for every run, i.e. zero lost
              requests;
  shed path   a deliberately tiny queue (queue_limit=64) is flooded
              with 1024 submits: exactly queue_limit are admitted, the
              rest shed with retry-after, and every handle resolves.

  PYTHONPATH=src python benchmarks/serve.py [--smoke]

Writes benchmarks/results/BENCH_serve.json (CI uploads it as an
artifact; the committed copy at the repo root feeds the README table).
"""
import argparse
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

import jax
import numpy as np

from common import emit, save_json

from repro.serve import ModelStore, RSUServer, ServePolicy, apply_reply

CODEC = "delta"
ROUNDS = 6          # published snapshots (round 0..5)
MAX_LAG = 4


def _fleet_tree(seed=0):
    """~1.9k params — the same small synthetic fleet model the comms
    benchmark prices; serving cost scales with tree bytes, not FLOPs."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    return {"conv": jax.random.normal(ks[0], (8, 3, 3)),
            "dense": jax.random.normal(ks[1], (48, 32)),
            "head": jax.random.normal(ks[2], (32, 8)),
            "bias": jax.random.normal(ks[3], (48,))}


def _publish_campaign(store):
    """ROUNDS snapshots, each a perturbation of the last — stands in for
    `run_campaign(publish=store.publish)` so the benchmark isolates
    serving throughput from training cost."""
    tree = _fleet_tree()
    for r in range(ROUNDS):
        key = jax.random.fold_in(jax.random.PRNGKey(99), r)
        ks = jax.random.split(key, len(jax.tree.leaves(tree)))
        it = iter(ks)
        tree = jax.tree.map(
            lambda l: l + 0.01 * jax.random.normal(next(it), l.shape), tree)
        store.publish(r, tree)
    return store


def _trees_equal(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _client(server, store, haves, out):
    """One fleet thread: submit a burst, wait, record latency."""
    lat, served, shed = [], 0, 0
    for i in range(0, len(haves), 128):
        pends = [server.submit(h) for h in haves[i:i + 128]]
        for p in pends:
            rep = p.result(timeout=60.0)
            lat.append((time.perf_counter() - p.t_submit) * 1e6)
            if rep.status == "ok":
                served += 1
            else:
                shed += 1
    out.append({"lat_us": lat, "served": served, "shed": shed})


def _lag_mix(rs, latest, n):
    """70% one round behind, 20% two behind, 10% ancient (-> full)."""
    draws = rs.rand(n)
    haves = np.full(n, latest - 1, np.int64)
    haves[draws >= 0.7] = latest - 2
    haves[draws >= 0.9] = -1
    return haves


def _parity_gate(store):
    """Bitwise decode parity on every reply shape vs the published tree."""
    policy = ServePolicy(max_lag=MAX_LAG)
    latest = store.latest_round
    checks = []
    for have in (latest - 1, latest - MAX_LAG):      # 1-hop and 4-hop chains
        from repro.serve import build_reply
        rep = build_reply(store, policy, have)
        assert rep.kind == "delta", rep.kind
        dec = apply_reply(rep, store.get(have).served_tree, codec=CODEC)
        checks.append(("delta", have, _trees_equal(dec,
                                                   store.get(rep.round).tree)))
    from repro.serve import build_reply
    rep = build_reply(store, ServePolicy(max_lag=0), latest - 1)
    assert rep.kind == "full", rep.kind
    dec = apply_reply(rep, None, codec=CODEC)
    checks.append(("full", latest - 1,
                   _trees_equal(dec, store.get(rep.round).tree)))
    for kind, have, ok in checks:
        if not ok:
            raise SystemExit(f"decode parity FAILED: kind={kind} have={have}")
    return [{"kind": k, "have_round": int(h), "bitwise": bool(ok)}
            for k, h, ok in checks]


def _shed_gate():
    """Flood a tiny bounded queue; prove shed accounting + zero loss."""
    store = _publish_campaign(ModelStore(codec=CODEC, window=ROUNDS + 2))
    policy = ServePolicy(queue_limit=64, retry_after_s=0.01)
    server = RSUServer(store, policy, start=False)
    pends = [server.submit(store.latest_round - 1) for _ in range(1024)]
    while server.drain_once(block=False):
        pass
    st = server.stats()
    if not all(p.done() for p in pends):
        raise SystemExit("shed path lost requests")
    if st["served"] != 64 or st["shed"] != 960:
        raise SystemExit(f"shed accounting off: {st}")
    if any(p.result().status == "shed" and p.result().retry_after_s <= 0
           for p in pends):
        raise SystemExit("shed replies missing retry-after backpressure")
    return {"submitted": st["submitted"], "served": st["served"],
            "shed": st["shed"], "lost": 0,
            "retry_after_s": policy.retry_after_s}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: smallest fleet only")
    a = ap.parse_args(argv)
    fleets = [1_000] if a.smoke else [1_000, 10_000, 100_000]
    n_threads = 8

    results = {"codec": CODEC, "rounds": ROUNDS, "max_lag": MAX_LAG,
               "fleets": []}
    store = _publish_campaign(ModelStore(codec=CODEC, window=ROUNDS + 2))
    latest = store.latest_round

    results["decode_parity"] = _parity_gate(store)
    print("decode parity (delta 1-hop, delta chain, full fallback): "
          "bitwise OK")

    for V in fleets:
        rs = np.random.RandomState(1234)
        haves = _lag_mix(rs, latest, V)
        server = RSUServer(store, ServePolicy(max_lag=MAX_LAG,
                                              queue_limit=max(4096, V)))
        out = []
        chunks = np.array_split(haves, n_threads)
        threads = [threading.Thread(target=_client,
                                    args=(server, store, list(c), out))
                   for c in chunks]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        server.stop()
        st = server.stats()
        served = sum(o["served"] for o in out)
        shed = sum(o["shed"] for o in out)
        if st["submitted"] != served + shed or shed != 0:
            raise SystemExit(f"accounting off at V={V}: {st} "
                             f"(client saw served={served} shed={shed})")
        lat = np.concatenate([np.asarray(o["lat_us"]) for o in out])
        p50, p99 = np.percentile(lat, 50), np.percentile(lat, 99)
        rate = served / wall
        emit(f"serve_fetch_V{V}", float(np.mean(lat)),
             f"{rate:.0f}/s p50={p50:.0f}us p99={p99:.0f}us")
        results["fleets"].append({
            "vehicles": int(V), "served": int(served), "shed": int(shed),
            "lost": int(st["submitted"] - served - shed),
            "models_per_sec": round(rate, 1),
            "p50_us": round(float(p50), 1), "p99_us": round(float(p99), 1),
            "batches": st["batches"], "groups": st["groups"],
            "max_queue_depth": st["max_depth"]})

    results["shed_path"] = _shed_gate()
    print(f"shed path: {results['shed_path']['shed']} shed of "
          f"{results['shed_path']['submitted']} with retry-after, 0 lost")

    save_json("BENCH_serve.json", results)
    print("wrote benchmarks/results/BENCH_serve.json")


if __name__ == "__main__":
    main()
