"""Compiled campaign: the whole round loop as pre-drawn schedules plus
one fused XLA program per round (core/engine.py).

Runs the SAME scenario through the eager loop and `run_campaign`, then
checks the engine contract on the spot:

  * the pre-drawn schedule (cohort velocities, lr, every record field
    except the loss) and the RNG successor states are bitwise identical
    to the eager loop;
  * chunked execution (checkpoint_every) is bitwise identical to the
    uninterrupted compiled campaign — pause/resume costs nothing;
  * the campaign compiles exactly ONE round program.

Doubles as the CI compiled-campaign smoke step.

  PYTHONPATH=src python examples/campaign.py [--rounds 4]
"""
import argparse
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    args = ap.parse_args()

    from repro.core.engine import compile_counts
    from repro.core.scenario import Scenario, run, run_campaign

    print("== FLSimCo compiled campaign ==")
    # small world so the fused round body compiles fast on CPU CI
    rs = np.random.RandomState(0)
    data = [rs.rand(16, 8, 8, 3).astype(np.float32) for _ in range(8)]
    sc = Scenario(topology="handover", data=data,
                  topology_kwargs={"n_rsus": 2, "rsu_range": 300.0,
                                   "round_duration": 40.0, "sync_every": 2},
                  n_vehicles=8, vehicles_per_round=3, batch_size=4,
                  rounds=args.rounds, local_iters=1, lr=0.4, seed=7)

    t0 = time.perf_counter()
    st_eager, hist_eager = run(sc)
    t_eager = time.perf_counter() - t0
    t0 = time.perf_counter()
    st_comp, hist_comp = run_campaign(sc, mode="auto", log_every=2)
    t_comp = time.perf_counter() - t0

    # schedule + RNG successors: bitwise vs the eager loop
    for a, b in zip(hist_eager, hist_comp):
        ae = {k: v for k, v in a.items() if k != "loss"}
        be = {k: v for k, v in b.items() if k != "loss"}
        assert ae == be, (ae, be)
    assert np.array_equal(np.asarray(st_eager.key), np.asarray(st_comp.key))
    for k in st_eager.host_rng:
        assert np.array_equal(np.asarray(st_eager.host_rng[k]),
                              np.asarray(st_comp.host_rng[k])), k
    assert np.array_equal(st_eager.topo["positions"],
                          st_comp.topo["positions"])
    print(f"schedule bitwise vs eager: OK "
          f"({len(hist_comp)} rounds, eager {t_eager:.1f}s, "
          f"compiled {t_comp:.1f}s incl. compile)")

    # chunked == unchunked, bit for bit (the checkpoint_every contract)
    with tempfile.TemporaryDirectory() as ckdir:
        st_ck, hist_ck = run_campaign(sc, mode="auto", checkpoint_every=2,
                                      checkpoint_dir=ckdir)
    assert hist_ck == hist_comp
    for a, b in zip(jax.tree.leaves(st_comp.to_tree()),
                    jax.tree.leaves(st_ck.to_tree())):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    print("chunked (checkpoint_every=2) bitwise == unchunked: OK")

    counts = compile_counts(sc)
    assert counts["jit_round"] <= 1 and counts["scan"] <= 2, counts
    print(f"compiled programs: {counts} (bounds: jit_round <= 1, "
          f"scan <= 2) — handover regrouping is data, not shape")
    print("done.")


if __name__ == "__main__":
    main()
