"""Handover demo: vehicles crossing RSU boundaries mid-training.

Declares a `HandoverMultiRSU` scenario on the synthetic vehicular world
and narrates each round: which RSU every participant downloaded from,
where it ended up uploading, which uploads were discounted as stale, and
when the regional server re-synchronized the RSU models. All motion
state (positions, per-RSU models, sync stats) lives in `FLState.topo`.

  PYTHONPATH=src python examples/handover.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.core.scenario import Scenario, run_round


def main():
    print("== FLSimCo multi-RSU handover demo ==")
    sc = Scenario(topology="handover",
                  topology_kwargs={"n_rsus": 3, "rsu_range": 500.0,
                                   "round_duration": 12.0,
                                   "stale_discount": 0.5, "sync_every": 3},
                  aggregator="flsimco", partitioner="dirichlet", alpha=0.1,
                  n_per_class=60, min_per_client=40,
                  n_vehicles=8, vehicles_per_round=4, batch_size=32,
                  rounds=6, local_iters=1, lr=0.5)
    topo = sc.topology
    print(f"road: ring of {topo.road_length:.0f} m, "
          f"{topo.n_rsus} RSUs x {topo.rsu_range:.0f} m coverage, "
          f"{sc.cfg.n_vehicles} vehicles\n")

    state = sc.init_state()
    history = []
    for _ in range(sc.cfg.rounds):
        pos_before = np.asarray(state.topo["positions"])
        state, rec = run_round(state, sc)
        history.append(rec)
        # unwrap across the ring boundary: forward distance, not raw delta
        moved = (np.asarray(state.topo["positions"])
                 - pos_before) % topo.road_length
        print(f"round {rec['round']}: loss={rec['loss']:.4f}  "
              f"uploads/RSU={rec['rsu_sizes']}  "
              f"handovers={rec['n_handovers']}"
              + ("  [region sync]" if rec["synced"] else ""))
        v = np.asarray(rec["velocities"])
        print(f"  velocities: {np.round(v * 3.6, 1).tolist()} km/h; "
              f"fleet moved {moved.min():.0f}-{moved.max():.0f} m")
    view = topo.region_view(state)  # evaluation snapshot (merged RSU models)
    n_params = sum(l.size for l in jax.tree.leaves(view))
    n_total = sum(h["n_handovers"] for h in history)
    print(f"\nregion model snapshot: {n_params:,} parameters "
          f"merged from {topo.n_rsus} RSUs")
    print(f"done — {n_total} handovers across {sc.cfg.rounds} rounds; "
          f"stale uploads were down-weighted x{topo.stale_discount}, "
          f"region re-synced every {topo.sync_every} rounds.")


if __name__ == "__main__":
    main()
