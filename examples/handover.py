"""Handover demo: vehicles crossing RSU boundaries mid-training.

Runs the HandoverMultiRSU topology on the synthetic vehicular world and
narrates each round: which RSU every participant downloaded from, where
it ended up uploading, which uploads were discounted as stale, and when
the regional server re-synchronized the RSU models.

  PYTHONPATH=src python examples/handover.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.federation import FLConfig, FederatedTrainer
from repro.core.topology import HandoverMultiRSU
from repro.data.synthetic import make_dataset, partition_dirichlet
from repro.models.resnet import init_resnet


def main():
    print("== FLSimCo multi-RSU handover demo ==")
    x, y = make_dataset(n_per_class=60, seed=0)
    parts = partition_dirichlet(y, n_clients=8, alpha=0.1,
                                min_per_client=40, seed=0)
    cfg = FLConfig(n_vehicles=8, vehicles_per_round=4, batch_size=32,
                   rounds=6, local_iters=1, lr=0.5, aggregator="flsimco")
    topo = HandoverMultiRSU(n_rsus=3, rsu_range=500.0, round_duration=12.0,
                            stale_discount=0.5, sync_every=3)
    tree = init_resnet(get_config("resnet18-cifar"), jax.random.PRNGKey(0))
    trainer = FederatedTrainer(cfg, tree, [x[p] for p in parts],
                               topology=topo)
    print(f"road: ring of {topo.road_length:.0f} m, "
          f"{topo.n_rsus} RSUs x {topo.rsu_range:.0f} m coverage, "
          f"{cfg.n_vehicles} vehicles\n")

    for r in range(cfg.rounds):
        pos_before = topo.positions.copy()
        rec = trainer.round(r)
        # unwrap across the ring boundary: forward distance, not raw delta
        moved = (topo.positions - pos_before) % topo.road_length
        print(f"round {r}: loss={rec['loss']:.4f}  "
              f"uploads/RSU={rec['rsu_sizes']}  "
              f"handovers={rec['n_handovers']}"
              + ("  [region sync]" if rec["synced"] else ""))
        v = np.asarray(rec["velocities"])
        print(f"  velocities: {np.round(v * 3.6, 1).tolist()} km/h; "
              f"fleet moved {moved.min():.0f}-{moved.max():.0f} m")
    view = topo.region_view()   # evaluation snapshot (merged RSU models)
    n_params = sum(l.size for l in jax.tree.leaves(view))
    n_total = sum(h["n_handovers"] for h in trainer.history)
    print(f"\nregion model snapshot: {n_params:,} parameters "
          f"merged from {topo.n_rsus} RSUs")
    print(f"done — {n_total} handovers across {cfg.rounds} rounds; "
          f"stale uploads were down-weighted x{topo.stale_discount}, "
          f"region re-synced every {topo.sync_every} rounds.")


if __name__ == "__main__":
    main()
