"""Mobility ablation: how velocity distribution shapes Eq.-11 weights and
convergence stability (the paper's Fig. 6 mechanism, isolated).

Sweeps the truncated-Gaussian mean velocity and reports (i) the blur-level
distribution, (ii) the aggregation-weight spread, (iii) the loss-gradient
std of short FLSimCo vs FedAvg runs at that mobility level.

  PYTHONPATH=src python examples/mobility_ablation.py --rounds 3
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.aggregation import flsimco_weights
from repro.core.federation import gradient_std
from repro.core.mobility import MobilityModel
from repro.core.scenario import Scenario, run
from repro.data.synthetic import make_dataset, partition_iid
from repro.models.resnet import init_resnet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--vehicles", type=int, default=8)
    ap.add_argument("--n-per-class", type=int, default=50)
    a = ap.parse_args()

    # one world for the whole sweep; the Scenarios share it via data=
    x, y = make_dataset(n_per_class=a.n_per_class, seed=0)
    data = [x[p] for p in partition_iid(y, a.vehicles)]
    tree = init_resnet(get_config("resnet18-cifar"), jax.random.PRNGKey(0))

    for mu in (20.0, 29.17, 38.0):
        mob = MobilityModel(mu=mu)
        v = np.asarray(mob.sample(jax.random.PRNGKey(1), 1000))
        L = np.asarray(mob.blur_level(v))
        w = np.asarray(flsimco_weights(mob.blur_level(
            mob.sample(jax.random.PRNGKey(2), 5))))
        print(f"\n-- mu = {mu:.1f} m/s ({mu*3.6:.0f} km/h) --")
        print(f"  blur L: mean {L.mean():.2f}, p95 {np.percentile(L,95):.2f},"
              f" frac>100km/h {(v > 27.78).mean():.2f}")
        print(f"  Eq.11 weight spread (5 vehicles): "
              f"{w.min():.3f}..{w.max():.3f}")
        for agg in ("flsimco", "fedavg"):
            sc = Scenario(aggregator=agg, mobility=mob, data=data,
                          global_tree=tree,
                          n_vehicles=a.vehicles, vehicles_per_round=4,
                          batch_size=32, rounds=a.rounds, lr=0.5, seed=0)
            _, hist = run(sc)
            losses = [h["loss"] for h in hist]
            print(f"  {agg:8s}: losses {[f'{l:.3f}' for l in losses]} "
                  f"grad_std={gradient_std(losses):.4f}")


if __name__ == "__main__":
    main()
