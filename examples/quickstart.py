"""Quickstart: one FLSimCo round, end to end, in under a minute on CPU.

Builds the synthetic vehicular dataset, runs a round of federated
dual-temperature SSL with blur-weighted aggregation, and prints the loss
and the Eq.-11 weights that the RSU assigned to each vehicle.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.aggregation import flsimco_weights
from repro.core.federation import FLConfig, FederatedTrainer
from repro.core.mobility import MobilityModel
from repro.data.synthetic import make_dataset, partition_dirichlet
from repro.models.resnet import init_resnet


def main():
    print("== FLSimCo quickstart ==")
    x, y = make_dataset(n_per_class=60, seed=0)
    parts = partition_dirichlet(y, n_clients=8, alpha=0.1,
                                min_per_client=40, seed=0)
    print(f"dataset: {len(x)} images, 8 vehicles (Dirichlet 0.1 Non-IID)")

    cfg = FLConfig(n_vehicles=8, vehicles_per_round=4, batch_size=32,
                   rounds=2, local_iters=1, lr=0.5, aggregator="flsimco")
    tree = init_resnet(get_config("resnet18-cifar"), jax.random.PRNGKey(0))
    trainer = FederatedTrainer(cfg, tree, [x[p] for p in parts])

    for r in range(cfg.rounds):
        rec = trainer.round(r)
        v = np.asarray(rec["velocities"])
        w = np.asarray(flsimco_weights(MobilityModel().blur_level(v)))
        print(f"round {r}: DT loss = {rec['loss']:.4f}")
        for i, (vi, wi) in enumerate(zip(v, w)):
            tag = " (blurred)" if vi > 27.78 else ""
            print(f"  vehicle {i}: v = {vi*3.6:6.1f} km/h -> "
                  f"aggregation weight {wi:.3f}{tag}")
    print("done — faster vehicles received lower weights (Eq. 11).")


if __name__ == "__main__":
    main()
