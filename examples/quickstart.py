"""Quickstart: one FLSimCo round, end to end, in under a minute on CPU.

Declares the experiment as a `Scenario` (synthetic vehicular dataset,
Dirichlet Non-IID split, blur-weighted aggregation), runs pure rounds
over an explicit `FLState`, and prints the loss and the Eq.-11 weights
that the RSU assigned to each vehicle.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.aggregation import flsimco_weights
from repro.core.mobility import MobilityModel
from repro.core.scenario import Scenario, run_round


def main():
    print("== FLSimCo quickstart ==")
    sc = Scenario(topology="single", aggregator="flsimco", client="dtssl",
                  partitioner="dirichlet", alpha=0.1, n_per_class=60,
                  min_per_client=40,
                  n_vehicles=8, vehicles_per_round=4, batch_size=32,
                  rounds=2, local_iters=1, lr=0.5)
    print(f"dataset: {len(sc.dataset[0])} images, "
          f"{sc.cfg.n_vehicles} vehicles (Dirichlet 0.1 Non-IID)")

    state = sc.init_state()
    for _ in range(sc.cfg.rounds):
        state, rec = run_round(state, sc)
        v = np.asarray(rec["velocities"])
        w = np.asarray(flsimco_weights(MobilityModel().blur_level(v)))
        print(f"round {rec['round']}: DT loss = {rec['loss']:.4f}")
        for i, (vi, wi) in enumerate(zip(v, w)):
            tag = " (blurred)" if vi > 27.78 else ""
            print(f"  vehicle {i}: v = {vi*3.6:6.1f} km/h -> "
                  f"aggregation weight {wi:.3f}{tag}")
    print("done — faster vehicles received lower weights (Eq. 11).")


if __name__ == "__main__":
    main()
