"""Checkpoint/resume demo + smoke check: pause-at-round-k is free.

Runs the same `Scenario` twice — once straight through, once saving the
full `FLState` at round k, restoring it from disk, and continuing — and
verifies the two end states are BIT-identical (model, RNG streams, and
round records all live in the state, so resuming loses nothing).

CI runs this as the resume-smoke step; it exits non-zero on any mismatch.

  PYTHONPATH=src python examples/resume.py --rounds 4 --save-at 2
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.checkpoint.store import restore_state, save_state
from repro.core.scenario import Scenario, run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--save-at", type=int, default=2)
    ap.add_argument("--topology", default="single")
    a = ap.parse_args()
    assert 0 < a.save_at < a.rounds, "--save-at must fall inside --rounds"

    topo_kwargs = {"handover": {"n_rsus": 2, "rsu_range": 300.0,
                                "round_duration": 30.0, "sync_every": 2},
                   "multi": {"n_rsus": 2}}.get(a.topology, {})
    sc = Scenario(topology=a.topology, topology_kwargs=topo_kwargs,
                  partitioner="iid", n_per_class=30,
                  n_vehicles=6, vehicles_per_round=2, batch_size=16,
                  rounds=a.rounds, lr=0.5)

    print(f"straight run: {a.rounds} rounds of {a.topology}")
    straight, hist_straight = run(sc, rounds=a.rounds)

    print(f"paused run: {a.save_at} rounds + save + restore + "
          f"{a.rounds - a.save_at} rounds")
    mid, hist_a = run(sc, rounds=a.save_at)
    with tempfile.TemporaryDirectory() as d:
        path = save_state(os.path.join(d, f"ckpt_{mid.round}.npz"), mid)
        print(f"  saved FLState at round {mid.round} "
              f"({os.path.getsize(path)/1e6:.1f} MB), restoring...")
        resumed_state = restore_state(path)
    resumed, hist_b = run(sc, resumed_state, rounds=a.rounds - a.save_at)

    mismatches = [
        i for i, (x, y) in enumerate(zip(jax.tree.leaves(straight.to_tree()),
                                         jax.tree.leaves(resumed.to_tree())))
        if not np.array_equal(np.asarray(x), np.asarray(y))]
    if mismatches or hist_straight != hist_a + hist_b:
        print(f"MISMATCH: leaves {mismatches}, "
              f"history equal: {hist_straight == hist_a + hist_b}")
        sys.exit(1)
    losses = [f"{h['loss']:.4f}" for h in hist_straight]
    print(f"losses: {losses}")
    print("resume is bit-identical to the uninterrupted run ✓")


if __name__ == "__main__":
    main()
