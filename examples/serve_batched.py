"""Transformer decode demo (prefill + KV-cache greedy decode) — NOT the
FL serving tier.

Scope: loads an architecture from the generic model zoo (reduced
variant by default so it runs on CPU), prefills a batch of prompts,
then decodes N tokens per sequence — the serve path the decode_32k /
long_500k dry-run shapes lower at production scale. Nothing here
touches federated rounds or RSU model distribution.

The FL edge-serving story (ROADMAP item 3, now closed) lives in
`repro.serve` instead: `ModelStore` snapshots delta-encoded through
`repro.comms`, plus an `RSUServer` with request batching and admission
control — see examples/serve_campaign.py for the train-and-serve demo
and benchmarks/serve.py for the measured throughput.

  PYTHONPATH=src python examples/serve_batched.py --arch tinyllama-1.1b \
      --reduced --tokens 16
  PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-1.6b \
      --reduced --long-context     # O(1)-state long-context decode
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--long-context", action="store_true")
    a = ap.parse_args()

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = cfg.reduced()
    print(f"== serving {cfg.name} ({cfg.family}) ==")
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)

    B, S = a.batch, a.prompt_len
    max_pos = S + a.tokens
    prompts = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    ctx_len = 8 if cfg.family == "audio" else 0
    aux = None
    if cfg.family == "vlm":
        aux = {"patches": jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_vision))}
    if cfg.family == "audio":
        aux = {"frames": jax.random.normal(key, (B, ctx_len, cfg.d_audio))}

    cache = T.init_cache(cfg, B, max_pos, dtype=jnp.float32,
                         long_context=a.long_context, ctx_len=ctx_len)
    t0 = time.time()
    prefill = jax.jit(lambda p, t, c: T.forward(
        cfg, p, t, mode="prefill", cache=c, aux_inputs=aux,
        long_context=a.long_context))
    logits, cache, _ = prefill(params, prompts, cache)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: {B}x{S} tokens in {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")

    decode = jax.jit(lambda p, t, c, pos: T.forward(
        cfg, p, t, mode="decode", cache=c, positions=pos,
        long_context=a.long_context))
    tok = jnp.argmax(logits[:, -1:, :cfg.vocab_size], axis=-1).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(a.tokens - 1):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits, cache, _ = decode(params, tok, cache, pos)
        tok = jnp.argmax(logits[:, :, :cfg.vocab_size], axis=-1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {a.tokens} steps x {B} seqs in {t_dec*1e3:.1f} ms "
          f"({(a.tokens-1)*B/max(t_dec,1e-9):.0f} tok/s)")
    print("generated ids (seq 0):", out[0].tolist())


if __name__ == "__main__":
    main()
