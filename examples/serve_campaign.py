"""Train-and-serve in one process: the RSU deployment loop end to end.

`run_campaign(publish=store.publish)` is the learner — each chunk's new
global model becomes an immutable `ModelStore` snapshot, delta-encoded
once through the `CODECS` registry. `RSUServer` is the distribution
actor — fetcher threads simulate vehicles pulling models WHILE the
campaign trains, applying delta chains (or the full-tree staleness
fallback) and verifying every decoded tree is bitwise equal to a
published `FLState` model. Checks on the spot:

  * every fetch resolves exactly once (served or shed-with-retry-after,
    never lost);
  * decoded trees match the published snapshots bit for bit;
  * the campaign still compiles exactly ONE round program — publishing
    rides the once-per-chunk history fetch, adding zero device syncs.

Doubles as the CI serve-smoke example.

  PYTHONPATH=src python examples/serve_campaign.py [--rounds 4]
"""
import argparse
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--fetchers", type=int, default=4)
    ap.add_argument("--codec", default="delta")
    args = ap.parse_args()

    import jax

    from repro.analysis.guards import assert_compile_bounds
    from repro.core.engine import compile_counts
    from repro.core.scenario import Scenario, run_campaign
    from repro.serve import ModelStore, RSUServer, ServePolicy, apply_reply

    print("== FLSimCo train-and-serve ==")
    rs = np.random.RandomState(0)
    data = [rs.rand(6, 4, 4, 3).astype(np.float32) for _ in range(8)]
    sc = Scenario(topology="single", data=data, n_vehicles=8,
                  vehicles_per_round=3, batch_size=2, rounds=args.rounds,
                  local_iters=1, lr=0.4, seed=7)

    store = ModelStore(codec=args.codec, window=args.rounds + 2)
    state0 = sc.init_state()
    store.publish(state0.round, state0.global_tree)
    server = RSUServer(store, ServePolicy(max_lag=4))

    def equal(a, b):
        return all(np.array_equal(np.asarray(x), np.asarray(y))
                   for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

    results = []

    def vehicle(seed):
        vrs = np.random.RandomState(seed)
        have_round = 0
        have_tree = store.get(0).served_tree
        fetched, mismatches = 0, 0
        deadline = time.perf_counter() + 60.0
        while time.perf_counter() < deadline:
            rep = server.submit(have_round).result(timeout=30.0)
            if rep.status == "shed":
                time.sleep(rep.retry_after_s)
                continue
            have_tree = apply_reply(rep, have_tree, codec=args.codec)
            have_round = rep.round
            fetched += 1
            snap = store.get(rep.round)
            if snap is not None and not equal(have_tree, snap.served_tree):
                mismatches += 1
            if have_round >= state0.round + args.rounds:
                break
            time.sleep(0.001 * vrs.rand())
        results.append({"fetched": fetched, "mismatches": mismatches})

    threads = [threading.Thread(target=vehicle, args=(i,))
               for i in range(args.fetchers)]
    for t in threads:
        t.start()
    state, hist = run_campaign(sc, state0, publish=store.publish,
                               publish_every=1)
    for t in threads:
        t.join()
    server.stop()

    fetched = sum(r["fetched"] for r in results)
    mism = sum(r["mismatches"] for r in results)
    st = server.stats()
    lost = st["submitted"] - st["served"] - st["shed"]
    assert mism == 0, f"{mism} decode mismatches"
    assert lost == 0, f"{lost} lost requests"
    assert all(r["fetched"] > 0 for r in results)
    print(f"{args.fetchers} vehicles fetched {fetched} models over "
          f"{len(hist)} trained rounds (codec={args.codec}); "
          f"decode parity bitwise OK, 0 lost")

    counts = compile_counts(sc)
    assert_compile_bounds(counts, what="train-and-serve campaign")
    print(f"compile bounds with publish hook: {counts}: OK")
    print(f"store: {store.stats()}, server: {st}")
    print("OK")


if __name__ == "__main__":
    main()
