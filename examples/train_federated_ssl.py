"""End-to-end driver: federated SSL pre-training + probe evaluation.

The paper's full experiment at configurable scale, declared as a
`Scenario` and driven through pure rounds. Defaults run a short
CPU-sized configuration; ``--preset paper`` reproduces Table 1 (95
vehicles, 520+ images each, batch 512, 150 rounds — hours on CPU).

  PYTHONPATH=src python examples/train_federated_ssl.py \
      --rounds 10 --vehicles 10 --aggregator flsimco --noniid

Checkpoints are FULL `FLState` snapshots (model + RNG streams + round),
so ``--resume`` continues bit-identically to a run that never paused.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.checkpoint.store import latest, restore_state, save_state
from repro.core.aggregation import AGGREGATORS
from repro.core.federation import gradient_std
from repro.core.scenario import Scenario, run_round
from repro.data.synthetic import make_dataset, partition_dirichlet, partition_iid
from repro.eval.probe import encode, knn_top1, linear_probe_top1


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["ci", "paper"], default="ci")
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--vehicles", type=int, default=10)
    ap.add_argument("--per-round", type=int, default=5)
    ap.add_argument("--local-iters", type=int, default=1)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--n-per-class", type=int, default=100)
    ap.add_argument("--lr", type=float, default=0.5)
    ap.add_argument("--aggregator", default="flsimco",
                    choices=sorted(AGGREGATORS) + ["fedco"])
    ap.add_argument("--client", default=None, choices=["dtssl", "fedco"])
    ap.add_argument("--topology", default="single",
                    choices=["single", "multi", "handover"])
    ap.add_argument("--noniid", action="store_true")
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--ckpt-dir", default="checkpoints/fl_ssl")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--probe", default="knn", choices=["knn", "linear"])
    a = ap.parse_args()

    if a.preset == "paper":  # Table 1
        a.rounds, a.vehicles, a.per_round = 150, 95, 5
        a.batch, a.n_per_class, a.lr = 512, 5000, 0.9

    x, y = make_dataset(n_per_class=a.n_per_class, seed=0)
    split = int(0.85 * len(x))
    xtr, ytr, xte, yte = x[:split], y[:split], x[split:], y[split:]
    if a.noniid:
        parts = partition_dirichlet(
            ytr, a.vehicles, a.alpha,
            min_per_client=min(520, len(xtr) // a.vehicles), seed=0)
    else:
        parts = partition_iid(ytr, a.vehicles)

    sc = Scenario(topology=a.topology, aggregator=a.aggregator,
                  client=a.client, data=[xtr[p] for p in parts],
                  n_vehicles=a.vehicles, vehicles_per_round=a.per_round,
                  batch_size=a.batch, rounds=a.rounds,
                  local_iters=a.local_iters, lr=a.lr)

    state = None
    if a.resume:
        found = latest(a.ckpt_dir)
        if found:
            state = restore_state(found[0], scenario=sc)
            print(f"resumed full FLState from {found[0]} "
                  f"(round {state.round})")
    if state is None:
        state = sc.init_state()

    history = []
    while state.round < a.rounds:
        state, rec = run_round(state, sc)
        history.append(rec)
        r = rec["round"]
        if r % 5 == 0 or r == a.rounds - 1:
            print(f"[{sc.cfg.aggregator}/{sc.cfg.client}] round {r:4d} "
                  f"loss={rec['loss']:.4f}")
        if state.round % a.ckpt_every == 0:
            save_state(os.path.join(a.ckpt_dir,
                                    f"ckpt_{state.round}.npz"), state,
                       scenario=sc)

    losses = [h["loss"] for h in history]
    if len(losses) > 1:
        print(f"gradient std of loss curve: {gradient_std(losses):.4f}")

    f_tr = encode(state.global_tree, xtr[:2000])
    f_te = encode(state.global_tree, xte[:1000])
    if a.probe == "knn":
        acc = knn_top1(f_tr, ytr[:2000], f_te, yte[:1000])
    else:
        acc = linear_probe_top1(f_tr, ytr[:2000], f_te, yte[:1000])
    print(f"{a.probe} probe top-1: {acc:.4f}")
    save_state(os.path.join(a.ckpt_dir, f"ckpt_{state.round}.npz"),
               state, scenario=sc)


if __name__ == "__main__":
    main()
