"""`repro.analysis` — trace hygiene as a tool, not a code-review habit.

This repo's own history is the motivation (ISSUE 8): PR 4 removed
per-client ``float(loss)`` syncs, PR 7 fixed a fresh-mesh-per-round
retrace bug, PR 6 hand-pinned ``jit_round <= 1`` inside one benchmark.
Every one of those regressions is mechanically detectable, so this
package detects them mechanically — statically and in CI, before they
ship:

  lint       AST linter over src/benchmarks/examples. Rule classes are
             mined from the real past bugs: host syncs in round/engine
             hot paths, retrace hazards (mesh/jit construction per
             round, fresh device constants per call), and purity
             violations (module-global mutation, RNG outside the packed
             RandomState / key-tree discipline). Findings carry
             file:line, rule id and a fix hint; `analysis/baseline.json`
             pins the accepted pre-existing set so CI fails only on NEW
             findings. `# analysis: sanctioned-sync -- reason` marks the
             once-per-round fetch points the design allows.

  contracts  Abstract (jax.eval_shape) interpretation of every
             AGGREGATORS / SCHEME_WEIGHTS / CLIENT_UPDATES / TOPOLOGIES
             registry entry against the declared pytree/shape/dtype/mask
             contracts — a new scheme is structurally validated at test
             time, not at round 50 of a campaign.

  guards     Runtime rails shared by the engine, tests and benchmarks:
             `no_implicit_transfers()` (jax.transfer_guard) around the
             fused round body, and `track_compiles()` /
             `assert_compile_bounds()` so the `jit_round <= 1` /
             `scan <= 2` campaign contract lives in exactly one place
             (`ENGINE_COMPILE_BOUNDS`).

Run the static layers from the repo root:

    python -m repro.analysis.lint src/ benchmarks/ examples/
    python -m repro.analysis.contracts

Import-light on purpose: `lint` is pure stdlib (usable without jax
installed), so submodules are imported explicitly, never from here.
"""
__all__ = ["contracts", "guards", "lint"]

