"""Abstract registry contract checker (``jax.eval_shape``, no FLOPs).

Every registry the experiment layer dispatches through has a structural
contract the rest of the stack assumes:

  SCHEME_WEIGHTS   (cohort, cfg) -> (n,) float weights over the VALID
                   rows only. A scheme that reads ``cohort.blur``
                   instead of ``cohort.valid_blur`` returns (m,) on a
                   padded cohort — the exact bug the valid-prefix
                   convention exists to prevent.
  AGGREGATORS      (cohort, cfg) -> pytree with the model tree's exact
                   structure, leaf shapes and dtypes (the new global
                   model), identical whatever the padding m >= n.
  CLIENT_UPDATES   run_cohort returns (CohortBatch, uploads) where the
                   CohortBatch carries the validity mask, per-row model
                   trees stacked over the cohort axis, and the same
                   valid count it was given.
  TOPOLOGIES       default-constructible strategy classes exposing the
                   Topology API with a JSON-able ``signature()``.
  CODECS           encode(stacked, base, ef) -> (payload, new_ef) with a
                   payload of concrete arrays and decode(payload, base)
                   reproducing the stacked trees' exact structure,
                   shapes and dtypes; stateful codecs must hand back a
                   residual of the shape they were given and declare a
                   round-0 state, stateless ones must declare neither.
  serve framing    (contract-serve) the serving tier's snapshot framing
                   over the same CODECS entries: encode_snapshot /
                   decode_snapshot must round-trip ONE model tree —
                   exactly what `ModelStore.publish` stores and the
                   vehicle decodes — back to the model treedef with
                   every leaf shape/dtype intact, and the payload must
                   be non-empty concrete arrays.

All checks interpret the registry entries abstractly — a ShapeDtypeStruct
cohort over a ShapeDtypeStruct resnet tree — so a broken scheme is
caught in milliseconds at test time, not at round 50 of a campaign.

Run from the repo root (CI's `analysis` job does)::

    python -m repro.analysis.contracts

Registries are injectable (``check_all(aggregators=..., ...)``) so
tests/test_analysis.py can verify the checker flags deliberately broken
entries with the right rule id.
"""
from __future__ import annotations

import json
import sys
from dataclasses import dataclass
from typing import Dict, List, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from ..core import aggregation as agg
from ..core import clients as clients_mod
from ..core import topology as topo_mod
from ..core.cohort import CohortBatch
from ..core.state import FLConfig
from ..configs.base import get_config
from ..models.resnet import init_resnet

__all__ = [
    "Violation",
    "check_aggregators",
    "check_all",
    "check_client_updates",
    "check_codecs",
    "check_scheme_weights",
    "check_serve",
    "check_topologies",
    "main",
]

# Rule ids (the analysis-wide namespace also holds the lint rules).
RULE_TREEDEF = "contract-treedef"
RULE_MASK = "contract-mask"
RULE_WEIGHT_SHAPE = "contract-weight-shape"
RULE_WEIGHT_DTYPE = "contract-weight-dtype"
RULE_TOPOLOGY_API = "contract-topology-api"
RULE_CODEC = "contract-codec"
RULE_SERVE = "contract-serve"
RULE_EVAL_ERROR = "contract-eval-error"


@dataclass(frozen=True)
class Violation:
    registry: str
    entry: str
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.registry}[{self.entry}]: {self.rule}: {self.message}"


# --------------------------------------------------------------------------
# abstract fixtures
# --------------------------------------------------------------------------

def _check_cfg(**over) -> FLConfig:
    """Tiny config: shapes only matter structurally under eval_shape."""
    base = dict(n_vehicles=8, vehicles_per_round=3, batch_size=2,
                local_iters=1, queue_len=16, feature_dim=128)
    base.update(over)
    return FLConfig(**base)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def model_tree_sds(arch: str = "resnet18-cifar"):
    """The model tree's shape/dtype skeleton, without allocating it."""
    model_cfg = get_config(arch)
    return jax.eval_shape(lambda k: init_resnet(model_cfg, k),
                          _sds((2,), jnp.uint32))


def abstract_cohort(tree_sds, n: int, m: int) -> CohortBatch:
    """A CohortBatch of ShapeDtypeStructs: n valid rows padded to m."""
    if not 1 <= n <= m:
        raise ValueError(f"valid count {n} not in [1, {m}]")
    stacked = jax.tree.map(lambda l: _sds((m,) + tuple(l.shape), l.dtype),
                           tree_sds)
    vec = _sds((m,), jnp.float32)
    return CohortBatch(trees=stacked, losses=vec, mask=vec, n=n,
                       velocities=vec, blur=vec)


def _leaf_paths(tree) -> Dict[str, jax.ShapeDtypeStruct]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def _diff_trees(expected, got) -> Optional[str]:
    """First structural difference between two SDS pytrees, or None."""
    es = jax.tree_util.tree_structure(expected)
    gs = jax.tree_util.tree_structure(got)
    if es != gs:
        return f"treedef mismatch: expected {es}, got {gs}"
    exp, act = _leaf_paths(expected), _leaf_paths(got)
    for path, leaf in exp.items():
        other = act[path]
        if tuple(other.shape) != tuple(leaf.shape):
            return (f"leaf {path or '<root>'} shape {tuple(other.shape)} "
                    f"!= expected {tuple(leaf.shape)}")
        if other.dtype != leaf.dtype:
            return (f"leaf {path or '<root>'} dtype {other.dtype} "
                    f"!= expected {leaf.dtype}")
    return None


# --------------------------------------------------------------------------
# per-registry checks
# --------------------------------------------------------------------------

# (n, m) cohort geometries every entry is interpreted under: the unpadded
# cohort and a bucketed one. Schemes/aggregators must be invariant to m.
_GEOMETRIES = ((3, 3), (3, 5))


def check_scheme_weights(schemes: Optional[Mapping] = None,
                         cfg: Optional[FLConfig] = None) -> List[Violation]:
    schemes = agg.SCHEME_WEIGHTS if schemes is None else schemes
    cfg = cfg or _check_cfg()
    tree = model_tree_sds()
    out: List[Violation] = []
    for name, fn in sorted(schemes.items()):
        for n, m in _GEOMETRIES:
            cohort = abstract_cohort(tree, n, m)
            try:
                w = jax.eval_shape(lambda c: fn(c, cfg), cohort)
            except Exception as e:  # noqa: BLE001 - report, don't crash
                out.append(Violation("SCHEME_WEIGHTS", name, RULE_EVAL_ERROR,
                                     f"raised under eval_shape at "
                                     f"(n={n}, m={m}): {e!r}"))
                break
            if tuple(w.shape) != (n,):
                hint = (" — weights computed on the padded rows; use "
                        "cohort.valid_blur / the valid-prefix views"
                        if tuple(w.shape) == (m,) and m != n else "")
                out.append(Violation(
                    "SCHEME_WEIGHTS", name, RULE_WEIGHT_SHAPE,
                    f"weights shape {tuple(w.shape)} != ({n},) at "
                    f"(n={n}, m={m}){hint}"))
                break
            if not jnp.issubdtype(w.dtype, jnp.floating):
                out.append(Violation(
                    "SCHEME_WEIGHTS", name, RULE_WEIGHT_DTYPE,
                    f"weights dtype {w.dtype} is not floating "
                    f"(aggregation multiplies f32 model leaves)"))
                break
    return out


def check_aggregators(aggregators: Optional[Mapping] = None,
                      cfg: Optional[FLConfig] = None) -> List[Violation]:
    aggregators = agg.AGGREGATORS if aggregators is None else aggregators
    cfg = cfg or _check_cfg()
    tree = model_tree_sds()
    out: List[Violation] = []
    for name, fn in sorted(aggregators.items()):
        for n, m in _GEOMETRIES:
            cohort = abstract_cohort(tree, n, m)
            try:
                result = jax.eval_shape(lambda c: fn(c, cfg), cohort)
            except Exception as e:  # noqa: BLE001
                out.append(Violation("AGGREGATORS", name, RULE_EVAL_ERROR,
                                     f"raised under eval_shape at "
                                     f"(n={n}, m={m}): {e!r}"))
                break
            diff = _diff_trees(tree, result)
            if diff is not None:
                out.append(Violation(
                    "AGGREGATORS", name, RULE_TREEDEF,
                    f"output is not the model tree at (n={n}, m={m}): "
                    f"{diff}"))
                break
    return out


def _check_one_client(name: str, entry, cfg: FLConfig, tree) -> List[Violation]:
    n = cfg.vehicles_per_round
    batches = _sds((n, cfg.batch_size, 4, 4, 3))
    keys = _sds((n, 2), jnp.uint32)
    lr = _sds(())

    def bad(rule, msg):
        return Violation("CLIENT_UPDATES", name, rule, msg)

    try:
        state = jax.eval_shape(lambda t: entry.init_state(cfg, t), tree)
        cohort, _uploads = jax.eval_shape(
            lambda t, cs, b, k, l: entry.run_cohort(cfg, t, cs, b, k, l,
                                                    parallel=True),
            tree, state, batches, keys, lr)
    except Exception as e:  # noqa: BLE001
        return [bad(RULE_EVAL_ERROR, f"raised under eval_shape: {e!r}")]

    if not isinstance(cohort, CohortBatch):
        return [bad(RULE_MASK,
                    f"run_cohort returned {type(cohort).__name__}, not a "
                    f"CohortBatch — the validity mask was dropped")]
    out: List[Violation] = []
    m = tuple(cohort.losses.shape)[0] if cohort.losses.ndim else 0
    if cohort.mask is None:
        out.append(bad(RULE_MASK, "CohortBatch.mask is None"))
    else:
        if tuple(cohort.mask.shape) != (m,):
            out.append(bad(RULE_MASK,
                           f"mask shape {tuple(cohort.mask.shape)} != "
                           f"losses' cohort axis ({m},)"))
        if not jnp.issubdtype(cohort.mask.dtype, jnp.floating):
            out.append(bad(RULE_MASK,
                           f"mask dtype {cohort.mask.dtype} is not the "
                           f"float32 validity convention"))
    if cohort.n != n:
        out.append(bad(RULE_MASK,
                       f"valid count changed: ran {n} clients, "
                       f"CohortBatch.n == {cohort.n}"))
    expected = jax.tree.map(lambda l: _sds((m,) + tuple(l.shape), l.dtype),
                            tree)
    diff = _diff_trees(expected, cohort.trees)
    if diff is not None:
        out.append(bad(RULE_TREEDEF,
                       f"stacked trees are not the model tree with a "
                       f"leading cohort axis: {diff}"))
    return out


def check_client_updates(client_updates: Optional[Mapping] = None,
                         cfg: Optional[FLConfig] = None) -> List[Violation]:
    client_updates = (clients_mod.CLIENT_UPDATES if client_updates is None
                      else client_updates)
    out: List[Violation] = []
    for name, entry in sorted(client_updates.items()):
        entry_cfg = cfg or _check_cfg(client=name if name in
                                      clients_mod.CLIENT_UPDATES else None)
        tree = model_tree_sds()
        out.extend(_check_one_client(name, entry, entry_cfg, tree))
    return out


def check_topologies(topologies: Optional[Mapping] = None) -> List[Violation]:
    topologies = topo_mod.TOPOLOGIES if topologies is None else topologies
    out: List[Violation] = []
    for name, cls in sorted(topologies.items()):
        def bad(rule, msg):
            return Violation("TOPOLOGIES", name, rule, msg)
        for method in ("init_state", "run_round", "signature", "validate"):
            if not callable(getattr(cls, method, None)):
                out.append(bad(RULE_TOPOLOGY_API,
                               f"missing Topology API method {method!r}"))
        try:
            instance = cls()
        except Exception as e:  # noqa: BLE001
            out.append(bad(RULE_TOPOLOGY_API,
                           f"not default-constructible: {e!r}"))
            continue
        if getattr(instance, "name", None) != name:
            out.append(bad(RULE_TOPOLOGY_API,
                           f"instance.name {getattr(instance, 'name', None)!r}"
                           f" != registry key {name!r}"))
        try:
            sig = instance.signature()
            json.dumps(sig)
        except Exception as e:  # noqa: BLE001
            out.append(bad(RULE_TOPOLOGY_API,
                           f"signature() is not JSON-able: {e!r}"))
            continue
        if not isinstance(sig, dict) or sig.get("name") != name:
            out.append(bad(RULE_TOPOLOGY_API,
                           f"signature() must be a dict carrying "
                           f"name={name!r}; got {sig!r}"))
    return out


def check_codecs(codecs: Optional[Mapping] = None,
                 cfg: Optional[FLConfig] = None) -> List[Violation]:
    """The comms-codec roundtrip contract, interpreted abstractly: for
    every cohort geometry, decode(encode(stacked)) must reproduce the
    stacked trees' structure/shapes/dtypes exactly (aggregation runs on
    the reconstruction), and the error-feedback residual must keep the
    shape it was given (it scatters back into ``FLState.comms``)."""
    from ..comms import codecs as codecs_mod
    codecs = codecs_mod.CODECS if codecs is None else codecs
    tree = model_tree_sds()
    out: List[Violation] = []
    for name, codec in sorted(codecs.items()):
        def bad(rule, msg):
            return Violation("CODECS", name, rule, msg)
        for _, m in _GEOMETRIES:
            entry_cfg = cfg or _check_cfg(vehicles_per_round=m)
            stacked = jax.tree.map(
                lambda l: _sds((m,) + tuple(l.shape), l.dtype), tree)
            try:
                state = jax.eval_shape(
                    lambda t: codec.init_state(entry_cfg, t), tree)
                if codec.stateful:
                    payload, new_ef = jax.eval_shape(
                        lambda s, b, e: codec.encode(s, b, e),
                        stacked, tree, state["ef"])
                else:
                    payload, new_ef = jax.eval_shape(
                        lambda s, b: codec.encode(s, b), stacked, tree)
                decoded = jax.eval_shape(
                    lambda p, b: codec.decode(p, b), payload, tree)
            except Exception as e:  # noqa: BLE001 - report, don't crash
                out.append(bad(RULE_EVAL_ERROR,
                               f"raised under eval_shape at m={m}: {e!r}"))
                break
            diff = _diff_trees(stacked, decoded)
            if diff is not None:
                out.append(bad(RULE_CODEC,
                               f"decode(encode(...)) is not the stacked "
                               f"cohort at m={m}: {diff}"))
                break
            if not jax.tree.leaves(payload):
                out.append(bad(RULE_CODEC, "encode returned an empty "
                                           "payload pytree"))
                break
            if codec.stateful:
                ef = state["ef"] if isinstance(state, dict) else None
                if ef is None:
                    out.append(bad(RULE_CODEC,
                                   "stateful codec without an 'ef' slot "
                                   "in init_state"))
                    break
                if new_ef is None or tuple(new_ef.shape) != tuple(ef.shape):
                    got = None if new_ef is None else tuple(new_ef.shape)
                    out.append(bad(RULE_CODEC,
                                   f"residual shape {got} != the "
                                   f"{tuple(ef.shape)} it was given"))
                    break
            elif state is not None or new_ef is not None:
                out.append(bad(RULE_CODEC,
                               "stateless codec declared cross-round "
                               "state (init_state / new_ef not None)"))
                break
    return out


def check_serve(codecs: Optional[Mapping] = None) -> List[Violation]:
    """The serving tier's snapshot-framing contract, interpreted
    abstractly: for every CODECS entry, ``encode_snapshot`` on a single
    model tree (against a base of the same tree — exactly what
    `ModelStore.publish` hands it from the `run_campaign` publish hook)
    must yield a non-empty payload, and ``decode_snapshot`` must invert
    it back to the model treedef with every leaf shape/dtype intact —
    the publish-hook output a vehicle reconstructs."""
    from ..comms import codecs as codecs_mod
    from ..comms.codecs import decode_snapshot, encode_snapshot
    codecs = codecs_mod.CODECS if codecs is None else codecs
    tree = model_tree_sds()
    out: List[Violation] = []
    for name, codec in sorted(codecs.items()):
        def bad(rule, msg):
            return Violation("CODECS", name, rule, msg)
        try:
            payload = jax.eval_shape(
                lambda t, b: encode_snapshot(codec, t, b), tree, tree)
            decoded = jax.eval_shape(
                lambda p, b: decode_snapshot(codec, p, b), payload, tree)
        except Exception as e:  # noqa: BLE001 - report, don't crash
            out.append(bad(RULE_EVAL_ERROR,
                           f"snapshot framing raised under eval_shape: "
                           f"{e!r}"))
            continue
        if not jax.tree.leaves(payload):
            out.append(bad(RULE_SERVE, "encode_snapshot returned an empty "
                                       "payload pytree"))
            continue
        diff = _diff_trees(tree, decoded)
        if diff is not None:
            out.append(bad(RULE_SERVE,
                           f"decode_snapshot(encode_snapshot(tree)) is not "
                           f"the model tree: {diff}"))
    return out


def check_all(*, schemes: Optional[Mapping] = None,
              aggregators: Optional[Mapping] = None,
              client_updates: Optional[Mapping] = None,
              topologies: Optional[Mapping] = None,
              codecs: Optional[Mapping] = None) -> List[Violation]:
    """Check every registry (real ones by default, injectable for tests)."""
    out: List[Violation] = []
    out.extend(check_scheme_weights(schemes))
    out.extend(check_aggregators(aggregators))
    out.extend(check_client_updates(client_updates))
    out.extend(check_topologies(topologies))
    out.extend(check_codecs(codecs))
    out.extend(check_serve(codecs))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    violations = check_all()
    for v in violations:
        print(str(v), file=sys.stderr)
    from ..comms import codecs as codecs_mod
    n_entries = (len(agg.SCHEME_WEIGHTS) + len(agg.AGGREGATORS)
                 + len(clients_mod.CLIENT_UPDATES) + len(topo_mod.TOPOLOGIES)
                 + len(codecs_mod.CODECS))
    if violations:
        print(f"contracts: {len(violations)} violation(s) across "
              f"{n_entries} registry entries", file=sys.stderr)
        return 1
    print(f"contracts: {n_entries} registry entries OK "
          f"(SCHEME_WEIGHTS={len(agg.SCHEME_WEIGHTS)}, "
          f"AGGREGATORS={len(agg.AGGREGATORS)}, "
          f"CLIENT_UPDATES={len(clients_mod.CLIENT_UPDATES)}, "
          f"TOPOLOGIES={len(topo_mod.TOPOLOGIES)}, "
          f"CODECS={len(codecs_mod.CODECS)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
