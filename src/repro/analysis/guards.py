"""Runtime guard rails shared by the engine, tests, and benchmarks.

Two rails, each previously enforced ad hoc (or not at all):

``no_implicit_transfers()``
    ``jax.transfer_guard("disallow")`` as a context manager. Inside it,
    any *implicit* host<->device transfer raises — a numpy array leaking
    into a jitted round body, a Python scalar uploaded mid-round, a
    traced value silently fetched by ``float()``. Explicit
    ``jax.device_get`` / ``jax.device_put`` stay allowed, which is
    exactly the repo's sanctioned-sync discipline: fetches are fine
    when they are deliberate and once per round/chunk. Note the guard
    is strict by design: even ``jnp.ones(3)`` trips it (the fill
    constant is an implicit upload), so warm/compile *outside* the
    guard and wrap only the steady-state dispatch of device-resident
    inputs — ``core.engine.run_campaign(transfer_guard=True)`` does
    this for the fused round body.

``track_compiles()`` / ``assert_compile_bounds()``
    A compile-count tracker backed by ``jax.monitoring``'s
    ``/jax/core/compile/backend_compile_duration`` event (one firing
    per XLA backend compile), with the engine's own trace counters
    layered on top. The ``jit_round <= 1`` / ``scan <= 2`` campaign
    contract from PR 6 lives here (``ENGINE_COMPILE_BOUNDS``) and
    nowhere else; ``benchmarks/round_engine.py`` and the engine tests
    import it instead of hand-pinning integers.

jax.monitoring has no public listener deregistration, so this module
registers ONE process-wide listener at first use and dispatches to
whichever trackers are currently active (re-entrant; trackers nest).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterator, Mapping, Optional

import jax

__all__ = [
    "ENGINE_COMPILE_BOUNDS",
    "CompileTracker",
    "GuardViolation",
    "assert_compile_bounds",
    "no_implicit_transfers",
    "track_compiles",
]

# The one home of the campaign-compilation contract (PR 6): a campaign
# traces the fused round body at most once per execution mode — one
# python-looped jit OR up to two scan programs (trailing chunk shorter
# than chunk length triggers the second trace).
ENGINE_COMPILE_BOUNDS: Dict[str, int] = {"jit_round": 1, "scan": 2}

# jax.monitoring event recorded once per XLA backend compile
# (jax 0.4.x; verified against this container's jaxlib).
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


class GuardViolation(AssertionError):
    """A runtime guard-rail contract was violated."""


@dataclass
class CompileTracker:
    """Counts XLA backend compiles observed while active.

    ``backend_compiles`` is the raw number of backend_compile events
    seen between ``__enter__`` and ``__exit__`` (or since the last
    ``reset()``). Use via :func:`track_compiles`.
    """

    backend_compiles: int = 0
    _active: bool = field(default=False, repr=False)

    def reset(self) -> None:
        self.backend_compiles = 0

    def _record(self) -> None:
        if self._active:
            self.backend_compiles += 1


_LOCK = threading.Lock()
_TRACKERS: list = []
_LISTENER_REGISTERED = False


def _dispatch(event: str, duration: float, **kwargs) -> None:
    if event != _COMPILE_EVENT:
        return
    with _LOCK:
        active = list(_TRACKERS)
    for tracker in active:
        tracker._record()


def _ensure_listener() -> None:
    # analysis: allow=purity-global-mutation -- jax.monitoring has no
    # unregister; one process-wide listener, registered exactly once
    global _LISTENER_REGISTERED
    with _LOCK:
        if _LISTENER_REGISTERED:
            return
        jax.monitoring.register_event_duration_secs_listener(_dispatch)
        _LISTENER_REGISTERED = True


@contextlib.contextmanager
def track_compiles() -> Iterator[CompileTracker]:
    """Count XLA backend compiles inside the ``with`` block.

    >>> with track_compiles() as tracker:
    ...     fn(x)  # warmed already?
    >>> assert tracker.backend_compiles == 0
    """
    _ensure_listener()
    tracker = CompileTracker()
    tracker._active = True
    with _LOCK:
        _TRACKERS.append(tracker)
    try:
        yield tracker
    finally:
        tracker._active = False
        with _LOCK:
            _TRACKERS.remove(tracker)


@contextlib.contextmanager
def no_implicit_transfers() -> Iterator[None]:
    """Raise on any implicit host<->device transfer in the block.

    Wraps ``jax.transfer_guard("disallow")``; explicit
    ``jax.device_get`` / ``jax.device_put`` remain allowed. Compile
    outside the guard — constant uploads during lowering trip it.
    """
    with jax.transfer_guard("disallow"):
        yield


def assert_compile_bounds(
    counts: Mapping[str, int],
    bounds: Optional[Mapping[str, int]] = None,
    *,
    what: str = "campaign",
) -> None:
    """Assert every counter in ``counts`` is within ``bounds``.

    ``bounds`` defaults to :data:`ENGINE_COMPILE_BOUNDS`. Counters in
    ``counts`` with no declared bound are ignored, so callers can pass
    ``core.engine.compile_counts(scenario)`` verbatim. Raises
    :class:`GuardViolation` naming every exceeded counter.
    """
    if bounds is None:
        bounds = ENGINE_COMPILE_BOUNDS
    exceeded = {
        name: (counts[name], limit)
        for name, limit in bounds.items()
        if counts.get(name, 0) > limit
    }
    if exceeded:
        detail = ", ".join(
            f"{name}={got} > {limit}" for name, (got, limit) in sorted(exceeded.items())
        )
        raise GuardViolation(
            f"{what} compile bounds exceeded: {detail} "
            f"(observed counts: {dict(counts)!r})"
        )
