"""Trace-hygiene AST linter — rules mined from this repo's real bugs.

The FL stack only hits its performance contracts while every round stays
compiled and device-resident. Three classes of regression have actually
happened here and are all statically visible:

  host-sync       PR 4 removed per-client ``float(loss)`` device syncs
                  from the round path. Rules: ``host-sync-cast``
                  (float()/int() on non-trivial expressions inside hot
                  scopes), ``host-sync-fetch`` (jax.device_get /
                  block_until_ready / .item() / np.asarray outside the
                  sanctioned once-per-round fetch points).
  retrace-hazard  PR 7 fixed MultiRSU building a fresh jax.make_mesh
                  every round (a retrace per round). Rules:
                  ``retrace-ctor`` (Mesh/NamedSharding/jit/shard_map
                  constructed inside an uncached function instead of
                  cached module scope), ``retrace-static-unhashable``
                  (list/dict static_argnums — a non-hashable jit cache
                  key), ``retrace-fresh-array`` (jnp constants rebuilt
                  per call in a hot scope — host->device churn).
  purity          Registry-registered functions must be pure in the
                  `run_round(state, scenario)` sense. Rules:
                  ``purity-global-mutation`` (``global`` rebinding),
                  ``purity-np-random`` (the process-global numpy RNG
                  instead of the packed RandomState from core/state.py),
                  ``purity-fresh-prngkey`` (jax.random.PRNGKey minted
                  inside a hot scope instead of threading FLState.key).

Hot scopes are functions whose names match ``HOT_NAME_RE`` (the round /
engine / aggregation vocabulary of this codebase) plus anything nested
inside them; retrace and purity rules apply everywhere.

Suppression is explicit and auditable:

  * ``# analysis: sanctioned-sync -- <reason>`` on the offending line
    marks a designed host<->device fetch point (suppresses the
    host-sync rules there);
  * ``# analysis: allow=<rule-id> -- <reason>`` suppresses one rule on
    that line;
  * ``analysis/baseline.json`` pins the accepted pre-existing findings
    (fingerprinted by path + rule + source text, so line drift does not
    invalidate it). CI fails only on findings beyond the baseline.

CLI (exit 0 iff no unsuppressed, non-baselined findings):

    python -m repro.analysis.lint src/ benchmarks/ examples/
    python -m repro.analysis.lint src/ --write-baseline   # refresh pins

Pure stdlib: no jax import, safe to run in a bare CI step.
"""
from __future__ import annotations

import argparse
import ast
import json
import os
import re
import sys
from collections import Counter
from dataclasses import dataclass, field
from typing import Iterable, List, Optional

DEFAULT_BASELINE = os.path.join("analysis", "baseline.json")

# Function names that constitute the per-round / per-dispatch hot path.
# Nested functions inherit hotness from their enclosing scope.
HOT_NAME_RE = re.compile(
    r"^(run_round|run_cohort|run_campaign|plan_round|body|_scan"
    r"|local_train|loss_fn|_record_fetch|_client_images|_client_batch"
    r"|_draw_batches|_cohort_plan|_sample_cohort|_plan_\w+|_client_batches"
    r"|aggregate\w*|_weighted\w+|cohort_weighted_sum|sharded_\w+"
    r"|two_stage\w+|wagg\w*|finalize|_mesh_aggregate|region_view)$")

# Constructors whose per-call cost is a retrace / device-state rebuild.
RETRACE_CTORS = {
    "jax.jit", "jit", "jax.pmap", "pmap", "jax.make_mesh", "make_mesh",
    "Mesh", "jax.sharding.Mesh", "NamedSharding", "jax.sharding.NamedSharding",
    "shard_map", "jax.experimental.shard_map.shard_map",
}

# jnp array constructors: fresh device constants when called per round.
FRESH_ARRAY_CTORS = {
    "jnp.asarray", "jnp.array", "jnp.full", "jnp.full_like", "jnp.zeros",
    "jnp.ones", "jnp.arange", "jnp.linspace", "jnp.eye",
}

# Caching decorators that make in-function construction a non-hazard.
CACHING_DECORATORS = {
    "functools.lru_cache", "lru_cache", "functools.cache", "cache",
    "functools.cached_property", "cached_property",
}

_ALLOW_RE = re.compile(
    r"#\s*analysis:\s*(?:allow=(?P<rules>[\w,-]+)|(?P<sync>sanctioned-sync))"
    r"(?:\s*--\s*(?P<reason>.*))?")

HOST_SYNC_RULES = ("host-sync-cast", "host-sync-fetch")

RULE_HINTS = {
    "host-sync-cast":
        "float()/int() on a device value blocks until the array is "
        "fetched — keep losses/stats device-resident and fetch once per "
        "round (core/topology.py:_record_fetch), or mark the line "
        "'# analysis: sanctioned-sync -- <why>'",
    "host-sync-fetch":
        "device fetches belong at the sanctioned once-per-round/chunk "
        "points; move the fetch there or mark it "
        "'# analysis: sanctioned-sync -- <why>'",
    "retrace-ctor":
        "construct meshes/shardings/jitted callables once at module "
        "scope or behind functools.lru_cache (launch/mesh.py:cohort_mesh "
        "is the pattern); per-call construction retraces or re-enumerates "
        "devices every round",
    "retrace-static-unhashable":
        "static_argnums/static_argnames must be hashable (tuple, not "
        "list/dict) or every call re-keys the jit cache",
    "retrace-fresh-array":
        "hoist the constant to module scope or an lru_cache'd helper — "
        "rebuilding it per call uploads host->device every round "
        "(core/hierarchical.py:_count_scale is the pattern)",
    "purity-global-mutation":
        "registry entries are pure functions of (state, scenario); "
        "rebind state through FLState.replace, not module globals",
    "purity-np-random":
        "draw from the packed RandomState threaded through FLState "
        "(core/state.py pack/unpack_host_rng), never the process-global "
        "numpy RNG — global draws break bit-reproducible schedules",
    "purity-fresh-prngkey":
        "thread FLState.key / jax.random.split through the round instead "
        "of minting a fresh PRNGKey — fresh keys fork the reproducible "
        "key chain",
}


@dataclass(frozen=True)
class Finding:
    path: str
    line: int
    col: int
    rule: str
    message: str
    code: str            # stripped source line (fingerprint component)

    @property
    def hint(self) -> str:
        return RULE_HINTS.get(self.rule, "")

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline: path + rule +
        source text. Duplicate texts are disambiguated by count, not
        index, so unrelated edits above a finding never invalidate it."""
        return f"{self.path}::{self.rule}::{self.code}"

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule}: "
                f"{self.message}\n    {self.code}\n    hint: {self.hint}")


@dataclass
class Suppressions:
    """Per-file `# analysis:` comment directives, by line number.

    A directive is statement-aware: inline (or on a comment line inside
    a multi-line statement) it covers that whole statement; on a
    comment-only line it covers the simple statement starting directly
    below (only the header line of a compound statement — a directive
    must not blanket a whole `def`/`for` body).
    """
    allow: dict = field(default_factory=dict)        # line -> set(rules)

    @classmethod
    def scan(cls, source: str,
             tree: Optional[ast.AST] = None) -> "Suppressions":
        directives = []                              # (line, rules|None)
        for i, text in enumerate(source.splitlines(), start=1):
            m = _ALLOW_RE.search(text)
            if not m:
                continue
            rules = None
            if m.group("sync"):
                rules = set(HOST_SYNC_RULES)
            if m.group("rules"):
                rules = (rules or set()) | {
                    r.strip() for r in m.group("rules").split(",")}
            if rules:
                directives.append((i, rules))

        # line extents of every SIMPLE statement (no nested body)
        spans = []
        if tree is not None and directives:
            for node in ast.walk(tree):
                if isinstance(node, ast.stmt) and not hasattr(node, "body"):
                    spans.append((node.lineno, node.end_lineno or node.lineno))
            spans.sort()

        lines = source.splitlines()

        def _is_commentary(ln: int) -> bool:
            text = lines[ln - 1].strip() if ln - 1 < len(lines) else ""
            return not text or text.startswith("#")

        sup = cls()
        for line, rules in directives:
            covered = {line, line + 1}
            enclosing = [s for s in spans if s[0] <= line <= s[1]]
            if enclosing:                # inline within a statement
                lo, hi = max(enclosing, key=lambda s: s[0])
                covered.update(range(lo, hi + 1))
            else:                        # comment line: cover the next
                below = [s for s in spans if s[0] > line]  # statement,
                if below:                # bridging further comment lines
                    lo, hi = min(below)
                    if all(_is_commentary(ln) for ln in range(line + 1, lo)):
                        covered.update(range(lo, hi + 1))
            for ln in covered:
                sup.allow.setdefault(ln, set()).update(rules)
        return sup

    def suppresses(self, finding: Finding) -> bool:
        return finding.rule in self.allow.get(finding.line, ())


def _dotted(node: ast.AST) -> str:
    """Best-effort dotted name of a call target ('jax.device_get',
    'np.random.choice', ...); '' when it is not a plain name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _is_trivial_cast_arg(node: ast.AST) -> bool:
    """Arguments to float()/int() that are not device syncs: literals,
    len()-like calls, static shape metadata (``x.size``, ``x.ndim``,
    ``x.shape[i]``, ``jnp.shape(x)[i]`` are Python ints even on device
    arrays), and numpy-namespace results (``np.mean(...)`` returns a
    host value — if a device value crossed into numpy, the sync
    happened at the ``np.asarray`` boundary the fetch rule flags).
    Bare names stay flagged: ``float(loss)`` is the PR-4 bug shape."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Attribute) and node.attr in ("size", "ndim",
                                                         "n", "round"):
        return True
    if isinstance(node, ast.Subscript):
        v = node.value
        if isinstance(v, ast.Attribute) and v.attr == "shape":
            return True
        if isinstance(v, ast.Call) and _dotted(v.func) in ("jnp.shape",
                                                           "np.shape"):
            return True
        return False
    if isinstance(node, ast.Call):
        name = _dotted(node.func)
        return (name in {"len", "min", "max", "round", "abs", "sum", "ord",
                         "bool", "time.time", "time.perf_counter"}
                or name.startswith(("np.", "numpy.", "math.")))
    if isinstance(node, (ast.Name,)):
        return False
    if isinstance(node, (ast.BinOp,)):
        return (_is_trivial_cast_arg(node.left)
                and _is_trivial_cast_arg(node.right))
    if isinstance(node, ast.UnaryOp):
        return _is_trivial_cast_arg(node.operand)
    return False


class _Scope:
    def __init__(self, node, hot: bool, cached: bool):
        self.node = node
        self.hot = hot
        self.cached = cached


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, source: str):
        self.path = path
        self.lines = source.splitlines()
        self.findings: List[Finding] = []
        self.scopes: List[_Scope] = []

    # -- helpers -----------------------------------------------------------

    def _code(self, node) -> str:
        try:
            return self.lines[node.lineno - 1].strip()
        except IndexError:                       # pragma: no cover
            return ""

    def _emit(self, node, rule: str, message: str) -> None:
        self.findings.append(Finding(
            path=self.path, line=node.lineno, col=node.col_offset,
            rule=rule, message=message, code=self._code(node)))

    @property
    def _in_function(self) -> bool:
        return bool(self.scopes)

    @property
    def _hot(self) -> bool:
        return bool(self.scopes) and self.scopes[-1].hot

    @property
    def _cached(self) -> bool:
        return any(s.cached for s in self.scopes)

    # -- scope tracking ----------------------------------------------------

    def _visit_def(self, node) -> None:
        hot = bool(HOT_NAME_RE.match(node.name)) or self._hot
        cached = any(
            _dotted(d.func if isinstance(d, ast.Call) else d)
            in CACHING_DECORATORS
            for d in node.decorator_list)
        self.scopes.append(_Scope(node, hot, cached))
        self.generic_visit(node)
        self.scopes.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def

    # -- purity ------------------------------------------------------------

    def visit_Global(self, node: ast.Global) -> None:
        self._emit(node, "purity-global-mutation",
                   f"function rebinds module global(s) "
                   f"{', '.join(node.names)}")
        self.generic_visit(node)

    # -- calls carry almost every rule --------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        name = _dotted(node.func)

        # host-sync rules fire only inside hot scopes
        if self._hot:
            if name in ("float", "int") and node.args and \
                    not _is_trivial_cast_arg(node.args[0]):
                self._emit(node, "host-sync-cast",
                           f"{name}() on a non-trivial expression in hot "
                           f"scope '{self.scopes[-1].node.name}' — a "
                           f"device sync if the value is traced/resident")
            elif name in ("jax.device_get", "device_get",
                          "jax.block_until_ready", "block_until_ready",
                          "np.asarray", "np.array", "numpy.asarray",
                          "numpy.array", "onp.asarray") or \
                    (isinstance(node.func, ast.Attribute)
                     and node.func.attr in ("item", "block_until_ready")
                     and not isinstance(node.func.value, ast.Constant)):
                self._emit(node, "host-sync-fetch",
                           f"device fetch '{name or node.func.attr}' in "
                           f"hot scope "
                           f"'{self.scopes[-1].node.name}' outside a "
                           f"sanctioned fetch point")
            if name in FRESH_ARRAY_CTORS:
                self._emit(node, "retrace-fresh-array",
                           f"'{name}' builds a fresh device array every "
                           f"call of hot scope "
                           f"'{self.scopes[-1].node.name}'")
            if name in ("jax.random.PRNGKey", "PRNGKey",
                        "jax.random.key"):
                self._emit(node, "purity-fresh-prngkey",
                           f"fresh PRNG key minted inside hot scope "
                           f"'{self.scopes[-1].node.name}'")

        # retrace hazards fire in ANY uncached function scope
        if self._in_function and not self._cached and name in RETRACE_CTORS:
            self._emit(node, "retrace-ctor",
                       f"'{name}' constructed inside "
                       f"'{self.scopes[-1].node.name}' — cache it at "
                       f"module scope or behind functools.lru_cache")
        if name in ("jax.jit", "jit", "functools.partial", "partial"):
            for kw in node.keywords:
                if kw.arg in ("static_argnums", "static_argnames",
                              "donate_argnums") and \
                        isinstance(kw.value, (ast.List, ast.Dict,
                                              ast.Set)):
                    self._emit(node, "retrace-static-unhashable",
                               f"{kw.arg} given a non-hashable "
                               f"{type(kw.value).__name__.lower()} literal")

        # process-global numpy RNG: anywhere, any scope
        if name.startswith(("np.random.", "numpy.random.")) and \
                name.rsplit(".", 1)[-1] not in ("RandomState",
                                                "default_rng",
                                                "Generator", "SeedSequence"):
            self._emit(node, "purity-np-random",
                       f"process-global numpy RNG call '{name}'")

        self.generic_visit(node)


def lint_source(path: str, source: str) -> List[Finding]:
    """All findings for one file, suppression comments applied."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding(path=path, line=e.lineno or 1, col=e.offset or 0,
                        rule="parse-error", message=str(e.msg), code="")]
    visitor = _Visitor(path, source)
    visitor.visit(tree)
    sup = Suppressions.scan(source, tree)
    return [f for f in visitor.findings if not sup.suppresses(f)]


def iter_python_files(targets: Iterable[str]) -> Iterable[str]:
    for target in targets:
        if os.path.isfile(target):
            yield target
            continue
        for root, dirs, files in os.walk(target):
            dirs[:] = sorted(d for d in dirs
                             if d not in ("__pycache__", ".git", "results"))
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def lint_paths(targets: Iterable[str]) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_python_files(targets):
        with open(path, "r", encoding="utf-8") as fh:
            findings.extend(lint_source(os.path.normpath(path), fh.read()))
    return findings


# --------------------------------------------------------------------------
# baseline: accepted pre-existing findings, fingerprinted without line
# numbers so unrelated edits never invalidate them
# --------------------------------------------------------------------------

def baseline_counts(findings: Iterable[Finding]) -> Counter:
    return Counter(f.fingerprint() for f in findings)


def save_baseline(findings: Iterable[Finding], path: str) -> None:
    counts = baseline_counts(findings)
    payload = {
        "comment": "accepted pre-existing findings; refresh with "
                   "`python -m repro.analysis.lint <targets> "
                   "--write-baseline` and review the diff",
        "findings": [{"fingerprint": fp, "count": n}
                     for fp, n in sorted(counts.items())],
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(payload, fh, indent=1)
        fh.write("\n")


def load_baseline(path: str) -> Counter:
    with open(path, "r", encoding="utf-8") as fh:
        payload = json.load(fh)
    return Counter({e["fingerprint"]: int(e["count"])
                    for e in payload.get("findings", [])})


def apply_baseline(findings: List[Finding],
                   baseline: Counter) -> List[Finding]:
    """Findings beyond the baselined count per fingerprint. The first
    `count` occurrences of each fingerprint are accepted; extras (new
    code repeating an old pattern) are reported."""
    remaining = Counter(baseline)
    fresh = []
    for f in findings:
        fp = f.fingerprint()
        if remaining[fp] > 0:
            remaining[fp] -= 1
        else:
            fresh.append(f)
    return fresh


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------

def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Trace-hygiene linter for the FL stack "
                    "(host syncs, retrace hazards, purity).")
    ap.add_argument("targets", nargs="+",
                    help="files or directories to lint")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"baseline JSON (default {DEFAULT_BASELINE}; "
                         f"ignored when missing unless --strict-baseline)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline "
                         "and exit 0")
    ap.add_argument("--no-baseline", action="store_true",
                    help="report every finding, ignoring any baseline")
    ap.add_argument("--strict-baseline", action="store_true",
                    help="error if the baseline file is missing")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--rules", default=None,
                    help="comma list restricting reported rule ids")
    args = ap.parse_args(argv)

    findings = lint_paths(args.targets)
    if args.rules:
        keep = {r.strip() for r in args.rules.split(",")}
        findings = [f for f in findings if f.rule in keep]

    if args.write_baseline:
        save_baseline(findings, args.baseline)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    if not args.no_baseline and os.path.exists(args.baseline):
        findings = apply_baseline(findings, load_baseline(args.baseline))
    elif args.strict_baseline and not args.no_baseline:
        print(f"baseline {args.baseline} not found", file=sys.stderr)
        return 2

    if args.format == "json":
        print(json.dumps([f.__dict__ for f in findings], indent=1))
    else:
        for f in findings:
            print(f.format())
        by_rule = Counter(f.rule for f in findings)
        summary = ", ".join(f"{r}={n}" for r, n in sorted(by_rule.items()))
        print(f"{len(findings)} finding(s)"
              + (f" [{summary}]" if findings else ""))
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
