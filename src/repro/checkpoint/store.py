"""Pytree checkpointing: npz round-trip with structure metadata.

save(path, step, tree) / restore(path) -> (step, tree); `latest(dir)`
follows the LATEST pointer the saver maintains. Works for arbitrary nested
dict/list/tuple pytrees of jax/numpy arrays (params, optimizer state,
MoCo queues, FL round metadata).
"""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.kind == "V":  # bfloat16 & friends: store raw bits
            arrays[f"leaf_{i}"] = a.view(np.uint16 if a.dtype.itemsize == 2
                                         else np.uint8)
            arrays[f"dtype_{i}"] = np.frombuffer(
                str(l.dtype).encode(), dtype=np.uint8)
        else:
            arrays[f"leaf_{i}"] = a
    np.savez(path, __step__=np.int64(step),
             __treedef__=np.frombuffer(str(treedef).encode(), dtype=np.uint8),
             **arrays)
    # structure is reconstructed from an example tree at restore; we also
    # store the treedef repr for sanity checks
    d = os.path.dirname(path) or "."
    with open(os.path.join(d, "LATEST"), "w") as f:
        json.dump({"path": os.path.basename(path), "step": step}, f)
    return path


def restore(path: str, like) -> Tuple[int, Any]:
    """Restore into the structure of `like` (an example pytree)."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    z = np.load(path)
    step = int(z["__step__"])
    leaves, treedef = _flatten(like)
    import jax.numpy as jnp
    new_leaves = []
    for i in range(len(leaves)):
        a = z[f"leaf_{i}"]
        if f"dtype_{i}" in z:
            dt = jnp.dtype(bytes(z[f"dtype_{i}"]).decode())
            a = jnp.asarray(a).view(dt)
        else:
            a = jnp.asarray(a)
        new_leaves.append(a)
    for i, (old, new) in enumerate(zip(leaves, new_leaves)):
        if tuple(np.shape(old)) != tuple(new.shape):
            raise ValueError(f"checkpoint leaf {i} shape mismatch: "
                             f"{np.shape(old)} vs {new.shape}")
    return step, jax.tree.unflatten(treedef, new_leaves)


def latest(ckpt_dir: str):
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        meta = json.load(f)
    return os.path.join(ckpt_dir, meta["path"]), meta["step"]
