"""Pytree checkpointing: npz round-trip with *structural* metadata.

save(path, step, tree) / restore(path) -> (step, tree); `latest(dir)`
follows the LATEST pointer the saver maintains. Works for arbitrary
nested dict/list/tuple/None pytrees of jax/numpy arrays (params,
optimizer state, MoCo queues, full `FLState` payloads via
`FLState.to_tree()`).

The tree *structure* is serialized alongside the leaves (a JSON spec
mapping container nesting to leaf indices), so `restore(path)` rebuilds
the exact dict/list/tuple nesting with no example tree. Passing
`restore(path, like)` additionally validates leaf shapes against `like`
and reuses its treedef — the only way to round-trip custom node types
(e.g. NamedTuples), which the structural spec records as plain tuples.

Scalar/bool/int leaves round-trip as numpy arrays of their exact dtype
(int64 stays int64, float64 stays float64 — host-RNG state survives
bit-for-bit); bfloat16 & friends are stored as raw bits + a dtype tag.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _spec(tree, n_leaves: list) -> Any:
    """JSON-able structural spec. Leaf numbering follows jax.tree.flatten
    order (dicts iterate in sorted-key order, sequences in order, None is
    an empty subtree) so the spec indexes the same `leaf_i` arrays."""
    if tree is None:
        return {"t": "none"}
    if isinstance(tree, dict):
        keys = sorted(tree)
        return {"t": "dict", "k": keys,
                "c": [_spec(tree[k], n_leaves) for k in keys]}
    if isinstance(tree, (list, tuple)):
        kind = "list" if isinstance(tree, list) else "tuple"
        return {"t": kind, "c": [_spec(x, n_leaves) for x in tree]}
    n_leaves[0] += 1
    return {"t": "leaf", "i": n_leaves[0] - 1}


def _unspec(spec, leaves) -> Any:
    t = spec["t"]
    if t == "none":
        return None
    if t == "dict":
        return {k: _unspec(c, leaves) for k, c in zip(spec["k"], spec["c"])}
    if t == "list":
        return [_unspec(c, leaves) for c in spec["c"]]
    if t == "tuple":
        return tuple(_unspec(c, leaves) for c in spec["c"])
    assert t == "leaf", t
    return leaves[spec["i"]]


def save(path: str, step: int, tree) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(l)
        if a.dtype.kind == "V":  # bfloat16 & friends: store raw bits
            arrays[f"leaf_{i}"] = a.view(np.uint16 if a.dtype.itemsize == 2
                                         else np.uint8)
            arrays[f"dtype_{i}"] = np.frombuffer(
                str(l.dtype).encode(), dtype=np.uint8)
        else:
            arrays[f"leaf_{i}"] = a
    n = [0]
    spec = _spec(tree, n)
    if n[0] == len(leaves):
        arrays["__spec__"] = np.frombuffer(json.dumps(spec).encode(),
                                           dtype=np.uint8)
    # else: a custom registered node made the structural walk disagree with
    # jax's flatten — omit the spec so restore(path) fails actionably and
    # restore(path, like) remains the (still-correct) path for such trees
    np.savez(path, __step__=np.int64(step),
             __treedef__=np.frombuffer(str(treedef).encode(), dtype=np.uint8),
             **arrays)
    d = os.path.dirname(path) or "."
    with open(os.path.join(d, "LATEST"), "w") as f:
        json.dump({"path": os.path.basename(path), "step": step}, f)
    return path


def _load_leaf(z, i: int):
    import jax.numpy as jnp
    a = z[f"leaf_{i}"]
    if f"dtype_{i}" in z:
        dt = jnp.dtype(bytes(z[f"dtype_{i}"]).decode())
        return jnp.asarray(a).view(dt)
    # plain numpy: int64/float64 leaves (round counters, RNG state) must
    # not be narrowed by jnp's default-x32 conversion
    return a


def restore(path: str, like: Any = None) -> Tuple[int, Any]:
    """Restore a checkpoint.

    With `like=None` (default) the structure is rebuilt from the stored
    structural spec. With an example pytree, leaves are validated against
    `like`'s shapes and re-hung on `like`'s treedef (use this for custom
    node types the spec cannot express).
    """
    if not path.endswith(".npz"):
        path = path + ".npz"
    z = np.load(path)
    step = int(z["__step__"])
    if like is None:
        if "__spec__" not in z:
            raise ValueError(
                f"{path} predates structural specs; pass an example tree "
                "via restore(path, like)")
        spec = json.loads(bytes(z["__spec__"]).decode())
        n = sum(1 for k in z.files if k.startswith("leaf_"))
        leaves = [_load_leaf(z, i) for i in range(n)]
        return step, _unspec(spec, leaves)
    leaves, treedef = _flatten(like)
    new_leaves = [_load_leaf(z, i) for i in range(len(leaves))]
    for i, (old, new) in enumerate(zip(leaves, new_leaves)):
        if tuple(np.shape(old)) != tuple(np.shape(new)):
            raise ValueError(f"checkpoint leaf {i} shape mismatch: "
                             f"{np.shape(old)} vs {np.shape(new)}")
    return step, jax.tree.unflatten(treedef, new_leaves)


def latest(ckpt_dir: str):
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        meta = json.load(f)
    return os.path.join(ckpt_dir, meta["path"]), meta["step"]


# -- FLState convenience ----------------------------------------------------

def _scenario_fingerprint(scenario) -> dict:
    import dataclasses
    # topology.signature() carries the static topology PARAMETERS, not
    # just the name: a handover checkpoint taken under n_rsus=2 must not
    # resume under n_rsus=3 (the campaign engine would happily replay a
    # mixed schedule otherwise)
    sig = scenario.topology.signature()
    return {"cfg": dataclasses.asdict(scenario.cfg),
            "topology": scenario.topology.name,
            "topology_params": {k: v for k, v in sig.items() if k != "name"}}


def save_state(path: str, state, scenario=None) -> str:
    """Checkpoint a full `FLState` (core/state.py) at its current round.

    Pass the `Scenario` to stamp the checkpoint with an experiment
    fingerprint (FLConfig fields + topology name); `restore_state` then
    refuses to resume it under a different experiment.
    """
    p = save(path, state.round, state.to_tree())
    if scenario is not None:
        # sidecar written next to the npz (np.savez has no extra-JSON slot)
        npz = p if p.endswith(".npz") else p + ".npz"
        with open(npz + ".meta.json", "w") as f:
            json.dump(_scenario_fingerprint(scenario), f)
    return p


def restore_state(path: str, scenario=None):
    """Rebuild an `FLState` from a `save_state` checkpoint — structural,
    no example tree needed. Returns the state (its round is the step).

    With `scenario`, validates the stored experiment fingerprint (when
    one exists) so a checkpoint from a different client/aggregator/
    topology/schedule fails loudly instead of silently continuing a
    mixed experiment.
    """
    from repro.core.state import FLState
    if not path.endswith(".npz"):
        path = path + ".npz"
    if scenario is not None:
        meta_path = path + ".meta.json"
        if os.path.exists(meta_path):
            with open(meta_path) as f:
                stored = json.load(f)
            want = _scenario_fingerprint(scenario)
            if stored != want:
                diff = [k for k in want["cfg"]
                        if stored["cfg"].get(k) != want["cfg"][k]]
                if stored["topology"] != want["topology"]:
                    diff.append("topology")
                if stored.get("topology_params") != want["topology_params"]:
                    diff.append("topology_params")
                raise ValueError(
                    f"checkpoint {path} was written by a different "
                    f"experiment (mismatched: {diff}); refusing to resume. "
                    f"Pass scenario=None to override.")
    _, tree = restore(path)
    return FLState.from_tree(tree)
