"""Comms tier: delta-compressed model exchange (ROADMAP item 3)."""
from repro.comms.codecs import (CODECS, Codec, comms_init_state,
                                payload_nbytes, q8_backend,
                                roundtrip_cohort, set_q8_backend,
                                tree_nbytes)

__all__ = ["CODECS", "Codec", "comms_init_state", "payload_nbytes",
           "q8_backend", "roundtrip_cohort", "set_q8_backend",
           "tree_nbytes"]
