"""Model-exchange codecs — the ``CODECS`` registry (``FLConfig.codec``).

FLSimCo's binding constraint at fleet scale is the comms volume: every
round, every vehicle ships its full model tree to the RSU and downloads
the new global model (8 bytes/parameter/vehicle at f32, down + up). This
module makes the exchange a pluggable encode/decode stage, mirroring the
``AGGREGATORS``/``CLIENT_UPDATES`` registries:

  identity     today's exchange: the stacked trees pass through verbatim
               (the default — zero overhead, bit-identical behavior).
  delta        lossless delta upload: Δ_n = θ_n − θ encoded as the
               WRAPPING integer difference of the raw float bits
               (bitcast<int32>(θ_n) − bitcast<int32>(θ)). A plain float
               subtract does NOT round-trip (θ + (θ_n − θ) != θ_n in
               floating point); the bitcast-integer delta reconstructs
               θ_n bit for bit for ANY values, so decode-then-aggregate
               is bitwise-identical to today's aggregation for all five
               SCHEME_WEIGHTS schemes (tests/test_comms.py). Same bytes
               as f32 on the wire, but the downlink base θ is shared by
               the whole cohort (one broadcast per round instead of
               per-vehicle unicast) and near-converged deltas have tiny
               magnitudes — entropy-coder-friendly and the input the
               int8 tier quantizes.
  delta_int8   lossy delta upload: Δ_n raveled to one (m, P) f32 matrix
               and quantized blockwise to int8 (one f32 scale per
               `kernels.qdelta.BQ` = 256 parameters, round-half-even,
               zero-scale guard) with an ERROR-FEEDBACK residual: the
               previous round's quantization error is folded in before
               quantizing, so the error telescopes instead of
               accumulating. The residual lives in ``FLState.comms`` —
               one (vehicles_per_round, Ppad) f32 slot array, slot i =
               cohort position i (a documented approximation of
               per-client EF under cohort resampling). ~1.016
               bytes/parameter on the wire vs 4 for f32.

The aggregation itself NEVER runs in delta space: `roundtrip_cohort`
reconstructs θ̂_n = decode(encode(θ_n)) and hands the existing
aggregators the reconstructed cohort. θ + Σ w_n·Δ_n is only float-close
to Σ w_n·θ_n (the weights sum to 1, but float addition reassociates);
reconstruct-then-aggregate makes the lossless tier bit-exact on the host
path, the shard_mapped mesh path and inside the compiled engine bodies
with no per-scheme reasoning at all.

Every encode/decode is pure jnp/Pallas (jit- and shard_map-traceable,
row-wise over the cohort axis); the int8 quantize/dequantize dispatches
through kernels/ops.py — fused Pallas kernels on TPU, the jnp reference
path elsewhere, ``q8_backend("interpret")`` forcing the kernel anywhere
(the same backend contract as aggregation's `wagg_backend`).
"""
from __future__ import annotations

import contextlib
import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.kernels.qdelta import BQ

# Backend for the int8 quantize/dequantize kernels (mirrors
# aggregation._wagg_backend): auto = fused Pallas on TPU, jnp reference
# elsewhere; "interpret" forces the Pallas kernel in interpret mode.
_Q8_BACKENDS = ("auto", "fused", "interpret", "ref")
_q8_backend = "auto"


def set_q8_backend(mode: str) -> str:
    """Select the int8 codec backend; returns the previous mode."""
    # analysis: allow=purity-global-mutation -- the one deliberate
    # process-wide switch (scoped form: q8_backend() below)
    global _q8_backend
    if mode not in _Q8_BACKENDS:
        raise ValueError(f"q8 backend {mode!r} not in {_Q8_BACKENDS}")
    prev, _q8_backend = _q8_backend, mode
    return prev


@contextlib.contextmanager
def q8_backend(mode: str):
    """Scoped `set_q8_backend` (tests force 'interpret' through this)."""
    prev = set_q8_backend(mode)
    try:
        yield
    finally:
        set_q8_backend(prev)


# --------------------------------------------------------------------------
# byte accounting (static — works on ShapeDtypeStructs too)
# --------------------------------------------------------------------------

def tree_nbytes(tree) -> int:
    """Total bytes of a pytree of arrays (size x itemsize per leaf)."""
    return sum(int(l.size) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree.leaves(tree))


def payload_nbytes(payload) -> int:
    """Wire bytes of an encoded payload (payloads are plain pytrees of
    arrays, so the accounting is `tree_nbytes`)."""
    return tree_nbytes(payload)


def flat_width(tree) -> int:
    """Raveled width P of ONE model tree, rounded up to the quantization
    block BQ — the per-row error-feedback slot width."""
    P = sum(int(l.size) for l in jax.tree.leaves(tree))
    return -(-P // BQ) * BQ


# --------------------------------------------------------------------------
# ravel helpers (row-major, the same leaf order as kernels/ops.py)
# --------------------------------------------------------------------------

def _ravel_rows(stacked) -> jnp.ndarray:
    """Stacked tree (every leaf (m, ...)) -> one (m, P) f32 matrix."""
    leaves = jax.tree.leaves(stacked)
    m = leaves[0].shape[0]
    return jnp.concatenate(
        [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)


def _unravel_rows(flat, row_shapes, treedef):
    """(m, P) f32 -> a stacked tree with per-row leaf shapes
    `row_shapes` (f32 leaves; dtype casts happen at the base-add)."""
    m = flat.shape[0]
    out, off = [], 0
    for shape in row_shapes:
        n = 1
        for d in shape:
            n *= int(d)
        out.append(flat[:, off:off + n].reshape((m,) + tuple(shape)))
        off += n
    return jax.tree.unflatten(treedef, out)


def _int_twin(dtype) -> jnp.dtype:
    """The same-width signed integer dtype a float leaf bitcasts to."""
    return jnp.dtype(f"int{jnp.dtype(dtype).itemsize * 8}")


# --------------------------------------------------------------------------
# codec implementations
# --------------------------------------------------------------------------

def _identity_encode(stacked, base, ef=None, stacked_base=False):
    return {"trees": stacked}, None


def _identity_decode(payload, base, stacked_base=False):
    return payload["trees"]


def _delta_enc_leaf(x, b):
    b = jnp.broadcast_to(b, x.shape).astype(x.dtype)
    if jnp.issubdtype(x.dtype, jnp.floating):
        it = _int_twin(x.dtype)
        return (jax.lax.bitcast_convert_type(x, it)
                - jax.lax.bitcast_convert_type(b, it))
    return x - b


def _delta_dec_leaf(d, b):
    out_dtype = b.dtype
    b = jnp.broadcast_to(b, d.shape)
    if jnp.issubdtype(out_dtype, jnp.floating):
        it = _int_twin(out_dtype)
        raw = jax.lax.bitcast_convert_type(b, it) + d
        return jax.lax.bitcast_convert_type(raw, out_dtype)
    return (b + d).astype(out_dtype)


def _delta_encode(stacked, base, ef=None, stacked_base=False):
    """Wrapping bitcast-integer delta: integer subtraction wraps (two's
    complement), so decode's add undoes encode's subtract bit for bit,
    with no float rounding anywhere — exact for ANY values. Leafwise
    broadcasting handles single and stacked bases alike."""
    return {"delta": jax.tree.map(_delta_enc_leaf, stacked, base)}, None


def _delta_decode(payload, base, stacked_base=False):
    return jax.tree.map(lambda d, b: _delta_dec_leaf(d, b),
                        payload["delta"], base)


def _q8_delta_rows(stacked, base):
    """Per-row float delta, raveled to an (m, Ppad) f32 matrix with the
    tail zero-padded to the quantization block BQ."""
    delta = jax.tree.map(
        lambda x, b: x.astype(jnp.float32)
        - jnp.broadcast_to(b, x.shape).astype(jnp.float32),
        stacked, base)
    flat = _ravel_rows(delta)
    m, P = flat.shape
    pad = (-P) % BQ
    if pad:
        # analysis: allow=retrace-fresh-array -- device-side zero pad
        # to the quantization block; width follows P, nothing to hoist
        flat = jnp.concatenate([flat, jnp.zeros((m, pad), jnp.float32)],
                               axis=1)
    return flat


def _int8_encode(stacked, base, ef=None, stacked_base=False):
    from repro.kernels import ops as _kops   # deferred: keep comms light
    flat = _q8_delta_rows(stacked, base)
    if ef is None:
        ef = jnp.zeros_like(flat)
    codes, scales, new_ef = _kops.q8_encode_flat(flat, ef,
                                                 backend=_q8_backend)
    return {"codes": codes, "scales": scales}, new_ef


def _int8_decode(payload, base, stacked_base=False):
    from repro.kernels import ops as _kops
    flat = _kops.q8_decode_flat(payload["codes"], payload["scales"],
                                backend=_q8_backend)
    leaves, treedef = jax.tree.flatten(base)
    # stacked_base says whether `base` carries the per-row leading axis
    # (the handover download: each row's base is its RSU's model) — the
    # caller knows, guessing from shapes is ambiguous for small trees
    row_shapes = [tuple(l.shape[1:]) if stacked_base else tuple(l.shape)
                  for l in leaves]
    delta = _unravel_rows(flat, row_shapes, treedef)
    return jax.tree.map(
        lambda b, d: (jnp.broadcast_to(b, d.shape).astype(jnp.float32)
                      + d).astype(b.dtype),
        base, delta)


def _no_state(cfg, tree):
    return None


def _int8_init_state(cfg, tree):
    """Zero error-feedback residual: one slot per cohort position."""
    return {"ef": jnp.zeros((cfg.vehicles_per_round, flat_width(tree)),
                            jnp.float32)}


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class Codec:
    """One exchange codec.

    encode(stacked, base, ef, stacked_base) -> (payload, new_ef) — pure
        and ROW-WISE: row i of every output depends only on row i of
        the inputs, so group-wise application (MultiRSU / handover
        per-RSU groups) equals one full-cohort application. `base` is a
        single model tree (broadcast over rows), or — with
        stacked_base=True — a per-row stacked tree; `ef` is the
        (rows, Ppad) residual slice for stateful codecs, else None.
    decode(payload, base, stacked_base) -> stacked trees θ̂_n;
        bitwise-exact reconstruction for lossless codecs.
    init_state(cfg, tree) -> the round-0 ``FLState.comms`` payload
        (None when the codec carries no cross-round state).
    """

    name: str
    lossless: bool
    stateful: bool
    encode: Callable[..., Any]
    decode: Callable[..., Any]
    init_state: Callable[..., Optional[dict]]


CODECS = {
    "identity": Codec("identity", lossless=True, stateful=False,
                      encode=_identity_encode, decode=_identity_decode,
                      init_state=_no_state),
    "delta": Codec("delta", lossless=True, stateful=False,
                   encode=_delta_encode, decode=_delta_decode,
                   init_state=_no_state),
    "delta_int8": Codec("delta_int8", lossless=False, stateful=True,
                        encode=_int8_encode, decode=_int8_decode,
                        init_state=_int8_init_state),
}


def comms_init_state(cfg, tree) -> Optional[dict]:
    """The round-0 ``FLState.comms`` for cfg.codec."""
    return CODECS[cfg.codec].init_state(cfg, tree)


# --------------------------------------------------------------------------
# snapshot framing (the serving tier's single-tree payloads)
# --------------------------------------------------------------------------

def resolve_codec(codec) -> Codec:
    """A ``Codec`` from a registry name or a `Codec` instance (the
    injectable form the analysis contracts exercise)."""
    return CODECS[codec] if isinstance(codec, str) else codec


def encode_snapshot(codec, tree, base):
    """ONE model tree framed through a stacked-cohort codec: the tree
    gains a length-1 cohort axis and row 0 encodes against ``base`` (the
    model the fetching vehicle already holds; ignored by ``identity``).

    This is the serving tier's downlink payload format (serve/store.py):
    `ModelStore.publish` encodes round r ONCE as
    ``encode_snapshot(codec, model_r, served_{r-1})`` and every fetch
    for round r reuses the payload. Stateful codecs run with a zero
    residual — a snapshot is one payload per round, there is no
    cross-fetch error-feedback to telescope (lossy drift is handled by
    chaining each snapshot off the previous RECONSTRUCTION instead, so
    server and vehicles stay bitwise in step)."""
    codec = resolve_codec(codec)
    stacked = jax.tree.map(lambda l: l[None], tree)
    payload, _ = codec.encode(stacked, base)
    return payload


def decode_snapshot(codec, payload, base):
    """Invert `encode_snapshot`: decode the payload against ``base`` and
    strip the length-1 cohort axis — the vehicle-side reconstruction
    (bitwise equal to the published tree for lossless codecs)."""
    codec = resolve_codec(codec)
    stacked = codec.decode(payload, base)
    return jax.tree.map(lambda l: l[0], stacked)


# --------------------------------------------------------------------------
# the CohortBatch encode/decode stage
# --------------------------------------------------------------------------

def roundtrip_cohort(cfg, cohort, base, comms, rows=None,
                     stacked_base=False):
    """Encode->decode the cohort's VALID trees against `base` — the one
    insertion point every host exchange path shares (the compiled engine
    bodies call the same encode/decode pair on raw stacked trees).

    rows: static index array mapping cohort row -> error-feedback slot
    (slot = cohort position); None means slots [0, n) in order. Padding
    rows of a bucketed cohort are re-padded by replicating the last
    DECODED row — padding is masked out of every aggregation, and for
    lossless codecs the decoded rows equal the originals bitwise, so
    the padded cohort stays bit-identical too. Returns
    (cohort', comms').
    """
    if cfg.codec == "identity":
        return cohort, comms
    codec = CODECS[cfg.codec]
    ef = full_ef = None
    if codec.stateful:
        full_ef = comms["ef"]
        ef = full_ef[:cohort.n] if rows is None else full_ef[rows]
    payload, new_ef = codec.encode(cohort.valid_trees, base, ef,
                                   stacked_base=stacked_base)
    trees = codec.decode(payload, base, stacked_base=stacked_base)
    if cohort.size > cohort.n:
        pad = cohort.size - cohort.n

        def ext(x):
            reps = jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])
            return jnp.concatenate([x, reps])

        trees = jax.tree.map(ext, trees)
    new_cohort = dataclasses.replace(cohort, trees=trees)
    if codec.stateful:
        rows = slice(0, cohort.n) if rows is None else rows
        comms = {"ef": full_ef.at[rows].set(new_ef)}
    return new_cohort, comms
