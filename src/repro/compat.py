"""JAX version shims.

The pinned wheels (requirements.txt) predate three API graduations that
newer TPU images ship; every in-repo caller goes through these wrappers:

* `shard_map`: `jax.experimental.shard_map` (kwarg `check_rep`) ->
  `jax.shard_map` (kwarg `check_vma`).
* `set_mesh`: the ambient-mesh context manager. On 0.4.x a `Mesh` is
  itself the context manager; newer JAX uses `jax.set_mesh`.
* `get_abstract_mesh`: newer JAX reads the ambient mesh via
  `jax.sharding.get_abstract_mesh()`; 0.4.x keeps the physical mesh in
  thread-local resources. Both return an object with `.empty`,
  `.axis_names`, and `.shape`, which is all our callers touch.
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check: bool = False):
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check)


def set_mesh(mesh):
    """Context manager binding `mesh` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def get_abstract_mesh():
    """The ambient mesh (empty mesh when none is bound)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src.mesh import thread_resources
    return thread_resources.env.physical_mesh


def abstract_mesh(axis_sizes, axis_names):
    """AbstractMesh across the 0.4.x ((name, size), ...) and the newer
    (sizes, names) constructor signatures."""
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
