"""Config system: architecture + input-shape registry.

Every assigned architecture is a frozen dataclass instance built by its
``src/repro/configs/<id>.py`` module (one per arch, citing its source).
``reduced()`` derives the CPU smoke-test variant (<=2 layers, d_model<=512,
<=4 experts) from the same family definition so smoke tests exercise the
identical code path as the full config.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional

VOCAB_PAD_MULTIPLE = 2048  # clean model-axis sharding (16 * 128)


def pad_vocab(v: int, multiple: int = VOCAB_PAD_MULTIPLE) -> int:
    return int(math.ceil(v / multiple) * multiple)


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters. Family selects the block assembly."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio | resnet
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    citation: str = ""

    # --- attention variants -------------------------------------------------
    rope_theta: float = 10_000.0
    qkv_bias: bool = False                      # qwen2
    sliding_window: int = 0                     # 0 = full attention
    local_global_period: int = 0                # gemma2: 2 -> alternate local/global
    attn_logit_softcap: float = 0.0             # gemma2: 50.
    final_logit_softcap: float = 0.0            # gemma2: 30.
    attn_scale_override: float = 0.0            # 0 -> 1/sqrt(head_dim)

    # --- FFN ----------------------------------------------------------------
    act: str = "silu"                           # silu | gelu
    gated_mlp: bool = True                      # SwiGLU/GeGLU when True

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01
    # scatter: sort+scatter dispatch with global token ids (baseline)
    # ep:      shard_map expert-parallel all_to_all (§Perf iteration 2)
    # auto:    ep when a model-parallel mesh is ambient, else scatter
    moe_impl: str = "auto"
    n_shared_experts: int = 0                   # kimi-k2: 1 shared expert
    moe_first_dense_layers: int = 0             # kimi-k2: first layer dense

    # --- SSM / RWKV ----------------------------------------------------------
    ssm_state: int = 0                          # mamba state size (hymba 16)
    ssm_expand: int = 2
    rwkv_head_dim: int = 64

    # --- hybrid (hymba: parallel attn + ssm heads) ---------------------------
    hybrid_parallel: bool = False

    # --- VLM ----------------------------------------------------------------
    cross_attn_period: int = 0                  # llama3.2-vision: every 5th layer
    n_vision_tokens: int = 0
    d_vision: int = 0

    # --- audio / enc-dec -----------------------------------------------------
    n_encoder_layers: int = 0                   # seamless: 24
    d_audio: int = 0                            # frontend frame-embedding dim

    # --- norm / embedding ----------------------------------------------------
    norm: str = "rmsnorm"                       # rmsnorm | layernorm
    post_norm: bool = False                     # gemma2: post-block norms too
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False                   # gemma-style sqrt(d) scaling

    # --- long-context --------------------------------------------------------
    # native      : O(1)-state recurrence handles 500k (ssm / hybrid)
    # sliding_window: dense archs run long_500k with a ring-buffer KV cache
    long_context_mode: str = "sliding_window"
    long_context_window: int = 8192

    # --- derived -------------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Approximate total parameter count (embeddings + blocks)."""
        d, h = self.d_model, self.head_dim_
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        mlp_mult = 3 if self.gated_mlp else 2
        if self.is_moe:
            ff = mlp_mult * d * self.d_ff * (self.n_experts + self.n_shared_experts)
            ff += d * self.n_experts  # router
        else:
            ff = mlp_mult * d * self.d_ff
        per_layer = attn + ff
        if self.family == "ssm":  # rwkv6: no attn, tkn-shift mixing + wkv params
            per_layer = 6 * d * d + mlp_mult * d * self.d_ff
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            per_layer = attn + mlp_mult * d * self.d_ff + 2 * d * d_in + d_in * d
        n = self.n_layers * per_layer
        if self.cross_attn_period:
            n_cross = self.n_layers // self.cross_attn_period
            n += n_cross * (2 * d * d + 2 * d * (self.n_kv_heads * h))
        if self.is_encdec:
            n += self.n_encoder_layers * per_layer
        n += self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return int(n)

    def n_active_params(self) -> int:
        """Params touched per token (MoE: active experts only)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        mlp_mult = 3 if self.gated_mlp else 2
        dense_ff = mlp_mult * d * self.d_ff * (self.n_experts_active + self.n_shared_experts)
        h = self.head_dim_
        attn = d * (self.n_heads * h) + 2 * d * (self.n_kv_heads * h) + (self.n_heads * h) * d
        per_layer = attn + dense_ff + d * self.n_experts
        return int(self.n_layers * per_layer + self.padded_vocab * d * 2)

    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: same family/code path, tiny dims."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=2,
            d_model=min(self.d_model, 256),
            n_heads=min(self.n_heads, 4),
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=64,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
        )
        if self.n_experts:
            kw.update(n_experts=4, n_experts_active=2,
                      n_shared_experts=min(self.n_shared_experts, 1),
                      moe_first_dense_layers=min(self.moe_first_dense_layers, 1))
        if self.n_encoder_layers:
            kw.update(n_encoder_layers=2)
        if self.cross_attn_period:
            kw.update(cross_attn_period=2, n_vision_tokens=16, d_vision=64)
        if self.sliding_window:
            kw.update(sliding_window=32)
        if self.long_context_window:
            kw.update(long_context_window=64)
        if self.d_audio:
            kw.update(d_audio=64)
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # import for registration side-effect
    from repro.configs import (  # noqa: F401
        tinyllama_1_1b, seamless_m4t_large_v2, rwkv6_1_6b, hymba_1_5b,
        gemma2_27b, kimi_k2_1t_a32b, llama_3_2_vision_90b, olmoe_1b_7b,
        qwen2_0_5b, deepseek_67b, resnet18_cifar,
    )
