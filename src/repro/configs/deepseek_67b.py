"""DeepSeek-67B — llama-arch dense [arXiv:2401.02954]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
    citation="arXiv:2401.02954",
    act="silu",
    gated_mlp=True,
))
