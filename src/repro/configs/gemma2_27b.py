"""Gemma2-27B — local/global alternating attention, logit softcaps [arXiv:2408.00118]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    citation="arXiv:2408.00118",
    local_global_period=2,      # even layers: sliding window; odd: global
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    act="gelu",
    gated_mlp=True,             # GeGLU
    norm="rmsnorm",
    post_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    attn_scale_override=1.0 / (224 ** 0.5),  # query_pre_attn_scalar=224 for 27B
))
