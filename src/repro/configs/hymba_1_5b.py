"""Hymba-1.5B — parallel attention + mamba heads per layer [arXiv:2411.13676].

25 attention heads (GQA kv=5) in parallel with a selective-SSM branch
(state 16) inside every layer; outputs of the two branches are mean-fused
after per-branch normalization, per the Hymba paper. Attention uses a
sliding window in all but a few global layers; we model the window for
long-context serving (the SSM branch carries unbounded context).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    citation="arXiv:2411.13676",
    ssm_state=16,
    ssm_expand=2,
    hybrid_parallel=True,
    sliding_window=1024,
    long_context_mode="native",  # SSM branch is O(1)-state
))
