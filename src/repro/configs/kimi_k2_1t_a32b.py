"""Kimi-K2 1T-A32B — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2].

Paper-table config: 61 layers, d_model=7168, 64 heads (GQA kv=8),
per-expert d_ff=2048, 384 routed experts + 1 shared, top-8 routing,
first layer dense. vocab 163840.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    citation="arXiv:2501.kimi2",
    n_experts=384,
    n_experts_active=8,
    n_shared_experts=1,
    moe_first_dense_layers=1,
    act="silu",
    gated_mlp=True,
))
