"""Llama-3.2-Vision-90B — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

Language backbone only: 100 layers (80 self-attention + 20 gated
cross-attention, one every 5th layer), d_model=8192, 64 heads (GQA kv=8),
d_ff=28672, vocab 128256. The ViT vision encoder + projector is a STUB per
the assignment: input_specs() supplies precomputed patch embeddings
(B, n_vision_tokens, d_vision) which a linear projector maps into d_model
for the cross-attention keys/values.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    citation="hf:meta-llama/Llama-3.2-11B-Vision",
    cross_attn_period=5,
    n_vision_tokens=1601,      # 1 global + 1600 patches @ 560px
    d_vision=1280,
    act="silu",
    gated_mlp=True,
))
