"""OLMoE-1B-7B — 64 experts top-8 MoE [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    citation="arXiv:2409.02060",
    n_experts=64,
    n_experts_active=8,
    act="silu",
    gated_mlp=True,
))
