"""Qwen2-0.5B — GQA with QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    citation="arXiv:2407.10671",
    qkv_bias=True,
    tie_embeddings=True,
    act="silu",
    gated_mlp=True,
    rope_theta=1_000_000.0,
))
