"""Paper's own backbone: improved ResNet-18 with fixed 128-D projector.

FLSimCo (Section 5.1): "We adopt an improved ResNet-18 with a fixed
dimension of 128-D as the backbone model". CIFAR-style stem (3x3 conv,
no max-pool), BatchNorm, 128-D projection head for the dual-temperature
contrastive loss.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="resnet18-cifar",
    family="resnet",
    n_layers=18,
    d_model=512,          # final stage width
    n_heads=1,
    n_kv_heads=1,
    d_ff=128,             # projector output dim (128-D)
    vocab_size=10,        # CIFAR-10 classes (for the probe head)
    citation="FLSimCo Sec. 5.1 / arXiv:2203.17248 (SimCo)",
))
