"""RWKV6 "Finch" 1.6B — attention-free, data-dependent decay [arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                # wkv heads = d_model / rwkv_head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    citation="arXiv:2404.05892",
    rwkv_head_dim=64,
    gated_mlp=False,           # rwkv channel-mix: square-relu two-matrix FFN
    act="sqrelu",
    norm="layernorm",
    long_context_mode="native",  # O(1) recurrent state
))
