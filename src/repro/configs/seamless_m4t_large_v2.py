"""SeamlessM4T-large-v2 — enc-dec, multimodal [arXiv:2308.11596].

Backbone only: 24 encoder + 24 decoder layers, d_model=1024, 16 heads
(GQA kv=16 => MHA), d_ff=8192, vocab 256206 (padded for sharding). The
speech frontend (mel-spectrogram + conformer conv feature extractor) is a
STUB per the assignment: input_specs() supplies precomputed frame
embeddings of shape (B, T_frames, d_audio) which the encoder consumes
through a linear adapter.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,               # decoder layers
    n_encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    citation="arXiv:2308.11596",
    act="gelu",
    gated_mlp=False,
    norm="layernorm",
    d_audio=1024,
))
