"""Model aggregation schemes — the paper's core contribution (Eq. 11).

Two implementations of each scheme:

* **host-level** (`aggregate`): takes a list of client parameter pytrees —
  the faithful cross-device FL simulation used by the paper-repro examples
  and benchmarks.
* **mesh-level** (`weighted_psum_tree`): each client cohort lives on a
  slice of the (pod, data) mesh axes and aggregation is a single weighted
  all-reduce — the TPU-native production form used by launch/steps.py.
  Equivalence of the two is covered by tests/test_aggregation.py.

Registry (``AGGREGATORS``, the names ``FLConfig.aggregator`` accepts).
Every entry has the uniform dispatch signature

    aggregate(cohort: CohortBatch, cfg) -> tree

where `cohort` carries the STACKED client trees, the validity mask of a
bucketed (padded) cohort, and device-resident blur/velocities
(core/cohort.py) — so topologies route Step 4 through the registry with
zero per-scheme branching and zero unstack/restack churn; the underlying
``aggregate_*`` functions keep their minimal list-based signatures for
direct use.

  flsimco  — blur-weighted (Eq. 11), weight_n ∝ (ΣL − L_n)/ΣL — the paper
  fedavg   — baseline1: uniform average (McMahan et al.)
  discard  — baseline2: drop clients above cfg.blur_threshold, then fedavg
  softmax  — beyond-paper: w ∝ softmax(−L/T), scale-free in N
  inverse  — beyond-paper: w ∝ 1/(L+eps), inverse-variance-flavored

(The paper's baseline3, FedCo, is not an aggregation scheme but a client
*algorithm* — FedAvg parameters + a global negative queue — and lives in
the ``CLIENT_UPDATES`` registry, core/clients.py. ``aggregator="fedco"``
is accepted as a legacy alias that FLConfig normalizes to
``client="fedco", aggregator="fedavg"``.)

Host-side weighted sums route through the fused Pallas kernel
(kernels/wagg.py) on TPU — one HBM pass over the stacked cohort tensor
(with the validity mask applied in-kernel) instead of N tree-map passes —
and fall back to the jnp tree-map path off-TPU.
``wagg_backend("interpret")`` forces the kernel in interpret mode (used by
tests/test_topology.py to exercise the kernel path on CPU).
"""
from __future__ import annotations

import contextlib
from typing import Sequence

import jax
import jax.numpy as jnp


# Backend for host-side weighted tree sums:
#   auto      — fused Pallas kernel on TPU, jnp tree-map elsewhere
#   fused     — force the compiled Pallas kernel (TPU)
#   interpret — force the Pallas kernel in interpret mode (any backend)
#   tree      — force the jnp tree-map path
_WAGG_BACKENDS = ("auto", "fused", "interpret", "tree")
_wagg_backend = "auto"


def set_wagg_backend(mode: str) -> str:
    """Select the weighted-sum backend; returns the previous mode."""
    # analysis: allow=purity-global-mutation -- the one deliberate
    # process-wide switch (scoped form: wagg_backend() below)
    global _wagg_backend
    if mode not in _WAGG_BACKENDS:
        raise ValueError(f"wagg backend {mode!r} not in {_WAGG_BACKENDS}")
    prev, _wagg_backend = _wagg_backend, mode
    return prev


@contextlib.contextmanager
def wagg_backend(mode: str):
    """Scoped `set_wagg_backend` (tests force 'interpret' through this)."""
    prev = set_wagg_backend(mode)
    try:
        yield
    finally:
        set_wagg_backend(prev)


def _resolve_wagg_backend() -> str:
    if _wagg_backend != "auto":
        return _wagg_backend
    return "fused" if jax.default_backend() == "tpu" else "tree"


def _weighted_stacked_sum(stacked, weights, mask=None) -> object:
    """sum_m w_m * tree[m] over the leading cohort axis of a STACKED tree.

    Every host-side aggregation scheme funnels through here, so this is
    the single dispatch point between the fused kernel and the tree-map
    reference path. `mask` (m,) zeroes padding rows of a bucketed cohort
    (w*1.0 == w and w*0.0 == 0.0, so a masked padded sum is bit-exact
    versus the unpadded sum over the valid prefix).
    """
    # analysis: allow=retrace-fresh-array -- f32 normalization at the
    # aggregation boundary (no-op for device weights, traced in jit)
    weights = jnp.asarray(weights, jnp.float32)
    backend = _resolve_wagg_backend()
    if backend != "tree":
        from repro.kernels import ops as _kops  # deferred: keep core import-light
        return _kops.wagg_stacked(stacked, weights, mask=mask,
                                  interpret=(backend == "interpret"))

    if mask is not None:
        # analysis: allow=retrace-fresh-array -- same normalization
        weights = weights * jnp.asarray(mask, jnp.float32)

    def comb(leaf):
        out = jnp.tensordot(weights, leaf.astype(jnp.float32), axes=1)
        return out.astype(leaf.dtype)

    return jax.tree.map(comb, stacked)


def _weighted_tree_sum(trees: Sequence, weights) -> object:
    """sum_n w_n * tree_n over a LIST of pytrees (legacy boundary): one
    stack, then the stacked dispatch above."""
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *trees)
    return _weighted_stacked_sum(stacked, weights)


def cohort_weighted_sum(cohort, w_valid) -> object:
    """Weighted sum of a `CohortBatch`: (n,) weights over the valid rows,
    zero-padded to the bucketed size, applied to the stacked trees with
    the cohort's validity mask — no unstack/restack anywhere."""
    return _weighted_stacked_sum(cohort.trees, cohort.padded_weights(w_valid),
                                 mask=cohort.mask)


def flsimco_weights(blur_levels, normalize: bool = True):
    """Eq. (11) weights: w_n = (ΣL − L_n) / ΣL   [/ (N−1) when normalized].

    The literal equation's weights sum to N−1; `normalize=True` (default)
    rescales them to sum to 1, the only reading under which the paper's
    multi-vehicle experiments converge (DESIGN.md deviation #2).
    """
    L = jnp.asarray(blur_levels, jnp.float32)
    N = L.shape[0]
    total = jnp.sum(L)
    w = (total - L) / jnp.maximum(total, 1e-12)
    if normalize:
        s = jnp.sum(w)
        # degenerate cases (single client, or all-zero blur) -> uniform
        w = jnp.where(s > 1e-12, w / jnp.maximum(s, 1e-12),
                      jnp.full_like(w, 1.0 / N))
    return w


def aggregate_flsimco(trees: Sequence, blur_levels, normalize: bool = True):
    """Blur-level-weighted aggregation (FLSimCo, Eq. 11)."""
    return _weighted_tree_sum(trees, flsimco_weights(blur_levels, normalize))


def aggregate_fedavg(trees: Sequence, data_sizes=None):
    """Baseline1: FedAvg; optionally weighted by local dataset size."""
    n = len(trees)
    if data_sizes is None:
        # analysis: allow=retrace-fresh-array -- legacy list-API entry
        # point; the stacked path uses SCHEME_WEIGHTS, not this
        w = jnp.full((n,), 1.0 / n)
    else:
        # analysis: allow=retrace-fresh-array -- legacy list-API entry
        s = jnp.asarray(data_sizes, jnp.float32)
        w = s / jnp.sum(s)
    return _weighted_tree_sum(trees, w)


def discard_weights(blur_levels, threshold: float):
    """Baseline2 weights: uniform over clients with blur L <= threshold.

    If every client exceeds the threshold, falls back to plain FedAvg
    weights (the RSU cannot emit an empty model).
    """
    L = jnp.asarray(blur_levels, jnp.float32)
    keep = (L <= threshold).astype(jnp.float32)
    n_keep = jnp.sum(keep)
    return jnp.where(n_keep > 0, keep / jnp.maximum(n_keep, 1.0),
                     jnp.full_like(keep, 1.0 / keep.shape[0]))


def aggregate_discard(trees: Sequence, blur_levels, threshold: float):
    """Baseline2: drop clients whose BLUR LEVEL (Eq. 2) exceeds
    `threshold`, FedAvg the rest.

    The threshold is in blur units, matching the registry contract and
    the mesh path (launch/steps.py); `FLConfig.blur_threshold` defaults
    to the blur level of the paper's 100 km/h cutoff
    (`mobility.BLUR_KMH_100`).
    """
    return _weighted_tree_sum(trees, discard_weights(blur_levels, threshold))


# --------------------------------------------------------------------------
# beyond-paper weighting variants (EXPERIMENTS.md §Paper-claims ablation)
# --------------------------------------------------------------------------

def softmax_weights(blur_levels, temperature: float = 5.0):
    """w ∝ softmax(−L/T): exponential rather than linear blur penalty.

    The paper's Eq. 11 is linear in L, so with many vehicles the weight
    spread collapses (w_n → 1/N as N grows at fixed L spread). A softmax
    keeps relative penalties scale-free in N — our proposed variant.
    """
    L = jnp.asarray(blur_levels, jnp.float32)
    return jax.nn.softmax(-L / temperature)


def aggregate_softmax(trees: Sequence, blur_levels, temperature: float = 5.0):
    return _weighted_tree_sum(trees, softmax_weights(blur_levels, temperature))


def inverse_weights(blur_levels, eps: float = 1.0):
    """w ∝ 1/(L+eps): treats blur as noise std — inverse-variance-flavored."""
    L = jnp.asarray(blur_levels, jnp.float32)
    w = 1.0 / (L + eps)
    return w / jnp.sum(w)


def aggregate_inverse(trees: Sequence, blur_levels, eps: float = 1.0):
    return _weighted_tree_sum(trees, inverse_weights(blur_levels, eps))


# Uniform dispatch signature: (cohort, cfg) where `cohort` is a
# `CohortBatch` (stacked trees + validity mask + device-resident
# blur/velocities) and `cfg` supplies the scheme's knobs
# (normalize_weights, blur_threshold). Each scheme is fully described
# by its WEIGHT function (``SCHEME_WEIGHTS``: (cohort, cfg) -> (n,)
# weights over the valid rows); the dispatch entry is always the same
# masked weighted sum over those weights. The split exists so the
# sharded aggregation path (core/hierarchical.py) can reuse the exact
# weight values — bit-for-bit the same scheme, only the reduction runs
# under shard_map. Weights are computed on the static valid slice
# (`cohort.valid_blur`) and zero-padded, so a bucketed (padded) cohort
# aggregates bit-exactly like an unpadded one (tests/test_cohort.py).
# FLConfig validates its `aggregator` field against these dicts, so
# adding a SCHEME_WEIGHTS entry is the whole story for a new scheme.

def _weights_flsimco(cohort, cfg):
    return flsimco_weights(cohort.valid_blur,
                           getattr(cfg, "normalize_weights", True))


def _weights_fedavg(cohort, cfg):
    return jnp.full((cohort.n,), 1.0 / cohort.n, jnp.float32)


def _weights_discard(cohort, cfg):
    # thresholds the Eq.-2 BLUR LEVEL (not raw velocity) against
    # cfg.blur_threshold, as the registry documents
    return discard_weights(cohort.valid_blur, cfg.blur_threshold)


def _weights_softmax(cohort, cfg):
    return softmax_weights(cohort.valid_blur)


def _weights_inverse(cohort, cfg):
    return inverse_weights(cohort.valid_blur)


SCHEME_WEIGHTS = {
    "flsimco": _weights_flsimco,
    "fedavg": _weights_fedavg,
    "discard": _weights_discard,
    "softmax": _weights_softmax,
    "inverse": _weights_inverse,
}


def _make_dispatch(weight_fn):
    def dispatch(cohort, cfg):
        return cohort_weighted_sum(cohort, weight_fn(cohort, cfg))
    return dispatch


AGGREGATORS = {name: _make_dispatch(fn) for name, fn in SCHEME_WEIGHTS.items()}


# --------------------------------------------------------------------------
# mesh-level (collective) form
# --------------------------------------------------------------------------

def weighted_psum_tree(tree, weight, axis_names):
    """Per-cohort weighted all-reduce: params' <- Σ_cohorts w * params.

    Inside shard_map/pjit, `weight` is this cohort's *normalized* scalar
    weight (weights already sum to 1 across the axis). One psum over the
    federated axes replaces the RSU gather-scatter — Eq. 11 as a collective.
    """
    def red(x):
        y = x.astype(jnp.float32) * weight
        y = jax.lax.psum(y, axis_names)
        return y.astype(x.dtype)

    return jax.tree.map(red, tree)


def normalized_weight_on_axis(blur_level, axis_names, normalize: bool = True):
    """This cohort's Eq.-11 weight, computed collectively over the mesh axes.

    blur_level: scalar L for the local cohort. Uses two cheap psums
    (scalar) to form (ΣL − L)/ΣL / Σ_n weights without gathering models.
    """
    L = jnp.asarray(blur_level, jnp.float32)
    total = jax.lax.psum(L, axis_names)
    w = (total - L) / jnp.maximum(total, 1e-12)
    if normalize:
        wsum = jax.lax.psum(w, axis_names)
        n = jax.lax.psum(jnp.ones(()), axis_names)
        w = jnp.where(wsum > 1e-12, w / jnp.maximum(wsum, 1e-12), 1.0 / n)
    return w
