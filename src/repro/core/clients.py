"""Client update algorithms — the ``CLIENT_UPDATES`` registry.

The paper compares two client-side algorithms under the same federated
round structure: FLSimCo's dual-temperature SSL (Sec. 4 Step 2) and the
FedCo MoCo baseline (momentum key encoder + global negative queue). The
old trainer special-cased FedCo by string comparison
(``aggregator == "fedco"`` + a private ``_round_fedco``); here both are
entries in one registry with one signature, so every topology runs any
client algorithm through the same three hooks:

  init_state(cfg, global_tree)           -> client_state pytree (or None)
  run_cohort(cfg, tree, client_state, batches,
             keys, lr, parallel, pad_to) -> (CohortBatch, uploads)
  finalize(cfg, client_state,
           aggregated_tree, uploads)      -> new client_state

`run_cohort` returns a device-resident `CohortBatch` (core/cohort.py):
the vmapped result stays STACKED — no per-client unstacking, no
`float(loss)` device syncs; the topology fetches losses once per round
when it builds the record. `pad_to` pads the cohort to a bucketed size
(replicating the last batch/key; the mask marks the valid prefix) so
variable-size cohorts — the handover topology — reuse a bounded set of
compiled cohort-step sizes instead of recompiling per size.

`uploads` is whatever extra payload the vehicles send besides parameters
(FedCo: the k-value batches the RSU merges into the global queue; DT-SSL:
nothing). Aggregation of the parameter trees themselves is the
topology's job, through the ``AGGREGATORS`` registry — client algorithm
and aggregation scheme are orthogonal axes of a `Scenario`.

Jitted client steps are cached per (hyperparameter tuple), not per
trainer, so seed/aggregator/round-count sweeps reuse one compilation;
`cohort_step_cache_size(cfg)` exposes how many cohort shapes have been
compiled (benchmarks/round_engine.py asserts the bucketing bound).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ssl
from repro.core.cohort import CohortBatch
from repro.core.dt_loss import dt_loss_matrix, info_nce_loss
from repro.core.state import FLConfig
from repro.models.resnet import resnet_apply
from repro.optim.optimizers import sgd


# --------------------------------------------------------------------------
# per-client local training (ResNet / images)
# --------------------------------------------------------------------------

def _client_loss(tree, cfg: FLConfig, images, key):
    """pi1/pi2 views -> encoder -> DT loss. Returns (loss, new_tree)."""
    k1, k2 = jax.random.split(key)
    v1 = ssl.pi1(k1, images)
    v2 = ssl.pi2(k2, images)
    q, _, tree1 = resnet_apply(tree, v1, train=True)
    k, _, tree2 = resnet_apply(tree1, v2, train=True)
    loss = dt_loss_matrix(q, k, cfg.tau_alpha, cfg.tau_beta)
    return loss, tree2


def make_local_train_step(cfg: FLConfig):
    opt_init, opt_update = sgd(cfg.momentum, cfg.weight_decay)

    def local_train(tree, images, key, lr):
        """cfg.local_iters SGD steps on one client. Returns (tree, loss).

        The iteration loop is a *python* unroll, not lax.scan: XLA-CPU
        pessimizes convolutions inside while-loops (~25x slower measured),
        and local_iters is 1-2 in the paper.
        """
        opt_state = opt_init(tree["params"])
        losses = []
        for k in jax.random.split(key, cfg.local_iters):
            tree_c = tree

            def loss_fn(params):
                t = {"params": params, "state": tree_c["state"]}
                loss, t2 = _client_loss(t, cfg, images, k)
                return loss, t2["state"]

            (loss, new_state), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(tree_c["params"])
            new_params, opt_state = opt_update(tree_c["params"], grads,
                                               opt_state, lr)
            tree = {"params": new_params, "state": new_state}
            losses.append(loss)
        return tree, jnp.stack(losses).mean()

    return local_train


def make_moco_local_train_step(cfg: FLConfig):
    """FedCo client: InfoNCE against the (global) queue, EMA key encoder."""
    opt_init, opt_update = sgd(cfg.momentum, cfg.weight_decay)

    def local_train(tree, key_tree, queue, images, key, lr):
        # python unroll (see make_local_train_step for the XLA-CPU rationale)
        opt_state = opt_init(tree["params"])
        losses, kvec = [], None
        for k in jax.random.split(key, cfg.local_iters):
            k1, k2 = jax.random.split(k)
            v1 = ssl.pi1(k1, images)
            v2 = ssl.pi2(k2, images)
            tree_c, key_tree_c = tree, key_tree

            def loss_fn(params):
                t = {"params": params, "state": tree_c["state"]}
                q, _, t2 = resnet_apply(t, v1, train=True)
                kv, _, _ = resnet_apply(key_tree_c, v2, train=False)
                kv = jax.lax.stop_gradient(kv)
                return info_nce_loss(q, kv, queue), (t2["state"], kv)

            (loss, (new_state, kvec)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(tree_c["params"])
            new_params, opt_state = opt_update(tree_c["params"], grads,
                                               opt_state, lr)
            tree = {"params": new_params, "state": new_state}
            key_tree = {
                "params": ssl.momentum_update(key_tree_c["params"], new_params,
                                              cfg.moco_momentum),
                "state": new_state,
            }
            losses.append(loss)
        return tree, key_tree, kvec, jnp.stack(losses).mean()

    return local_train


# --------------------------------------------------------------------------
# shared jit caches (keyed on exactly the fields the step closes over)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _cached_local_steps(local_iters, momentum, weight_decay,
                        tau_alpha, tau_beta):
    f = make_local_train_step(FLConfig(
        local_iters=local_iters, momentum=momentum,
        weight_decay=weight_decay, tau_alpha=tau_alpha, tau_beta=tau_beta))
    # The cohort step vmaps with the init tree UNBATCHED (in_axes=None):
    # every client in a cohort starts the round from the same model, so
    # broadcasting N weight copies (the old form) only forced XLA into
    # batched-weight (grouped) convolutions for ops whose weights are
    # genuinely shared. vmap propagates the batch axis lazily — the
    # first local iteration runs shared-weight, later iterations (whose
    # trees have diverged per client) batched — and the result is
    # bit-exact with the sequential path (tests/test_federation.py).
    return jax.jit(f), jax.jit(jax.vmap(f, in_axes=(None, 0, 0, None)))


def _jitted_local_steps(cfg: FLConfig):
    return _cached_local_steps(cfg.local_iters, cfg.momentum,
                               cfg.weight_decay, cfg.tau_alpha, cfg.tau_beta)


@functools.lru_cache(maxsize=16)
def _cached_sharded_steps(local_iters, momentum, weight_decay,
                          tau_alpha, tau_beta, mesh):
    """The vmapped cohort step under shard_map: each device trains its
    block of the cohort (rows sharded over the mesh's federated axes),
    the init tree and lr replicated. Block width = cohort / devices, so
    vmap batching math runs at a DIFFERENT width than the single-device
    reference — float-close, never bitwise, versus the unsharded vmap
    (DESIGN.md §Sharded cohorts); bitwise-deterministic within the
    sharded mode itself."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    f = make_local_train_step(FLConfig(
        local_iters=local_iters, momentum=momentum,
        weight_decay=weight_decay, tau_alpha=tau_alpha, tau_beta=tau_beta))
    axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    vf = jax.vmap(f, in_axes=(None, 0, 0, None))
    return jax.jit(shard_map(
        vf, mesh=mesh,
        in_specs=(P(), P(axes), P(axes), P()),
        out_specs=(P(axes), P(axes)), check=False))


def _jitted_sharded_steps(cfg: FLConfig, mesh):
    return _cached_sharded_steps(cfg.local_iters, cfg.momentum,
                                 cfg.weight_decay, cfg.tau_alpha,
                                 cfg.tau_beta, mesh)


@functools.lru_cache(maxsize=16)
def _cached_raw_step(local_iters, momentum, weight_decay,
                     tau_alpha, tau_beta):
    return make_local_train_step(FLConfig(
        local_iters=local_iters, momentum=momentum,
        weight_decay=weight_decay, tau_alpha=tau_alpha, tau_beta=tau_beta))


def raw_local_step(cfg: FLConfig):
    """The UNJITTED per-client train step for cfg — the campaign engine
    (core/engine.py) vmaps and fuses this inside its own jitted round
    body, so wrapping it in jit here would only nest dispatch layers.
    Cached per hyperparameter tuple so engine callables built for the
    same cfg share one function object."""
    return _cached_raw_step(cfg.local_iters, cfg.momentum,
                            cfg.weight_decay, cfg.tau_alpha, cfg.tau_beta)


@functools.lru_cache(maxsize=16)
def _cached_moco_step(local_iters, momentum, weight_decay, moco_momentum):
    return jax.jit(make_moco_local_train_step(FLConfig(
        local_iters=local_iters, momentum=momentum,
        weight_decay=weight_decay, moco_momentum=moco_momentum)))


def _jitted_moco_step(cfg: FLConfig):
    return _cached_moco_step(cfg.local_iters, cfg.momentum,
                             cfg.weight_decay, cfg.moco_momentum)


def cohort_step_cache_size(cfg: FLConfig) -> int:
    """Number of compiled variants of cfg's VMAPPED cohort step — one per
    distinct (cohort size, batch shape). The handover bucketing policy
    bounds this by ceil(log2(vehicles_per_round)) + 1 per topology
    (benchmarks/round_engine.py reports it)."""
    _, vlocal = _jitted_local_steps(cfg)
    return vlocal._cache_size()


def reset_cohort_step_caches() -> None:
    """Drop every cached/compiled client step (benchmark isolation)."""
    _cached_local_steps.cache_clear()
    _cached_moco_step.cache_clear()
    _cached_raw_step.cache_clear()
    _cached_sharded_steps.cache_clear()


# --------------------------------------------------------------------------
# registry entries
# --------------------------------------------------------------------------

def _pad_cohort_inputs(batches, keys, pad_to: int):
    """Pad stacked batches/keys from n to pad_to rows by replicating the
    last valid row — NO RNG is consumed, so a padded cohort draws exactly
    the same host/jax random streams as an unpadded one. The replicated
    rows train on real (finite) data and are masked out of every
    aggregation downstream."""
    n = batches.shape[0]
    pad = pad_to - n
    if pad < 0:
        raise ValueError(f"pad_to={pad_to} smaller than cohort size {n}")
    if pad == 0:
        return batches, keys
    batches = jnp.concatenate(
        [batches, jnp.broadcast_to(batches[-1:], (pad,) + batches.shape[1:])])
    keys = jnp.concatenate(
        [keys, jnp.broadcast_to(keys[-1:], (pad,) + keys.shape[1:])])
    return batches, keys


class DTSSLClient:
    """FLSimCo Step 2: dual-temperature contrastive SSL. Stateless."""

    name = "dtssl"

    def init_state(self, cfg: FLConfig, global_tree):
        return None

    def run_cohort(self, cfg: FLConfig, tree, client_state, batches, keys,
                   lr, parallel: bool = True, pad_to: int | None = None,
                   mesh=None):
        """Run one cohort of clients from init model `tree`.

        `parallel=True` vmaps the cohort over a stacked tree and returns
        the result STACKED (a `CohortBatch`) — no unstacking, no host
        syncs; `pad_to` additionally pads the cohort to a bucketed size
        so variable-size cohorts share compilations. The sequential path
        is the tested-equivalent reference (tests/test_federation.py,
        tests/test_topology.py).

        `mesh` (a cohort mesh, launch/mesh.py) additionally shards the
        cohort rows over the mesh's federated axes: each device vmaps its
        own block. Pads to a multiple of the mesh extent (replicated last
        row — no RNG consumed, padding masked out downstream), so a
        cohort smaller than the mesh still runs. The block-sharded vmap
        batches at a different width than the single-device reference, so
        this path is float-close, not bitwise, versus `parallel=True`
        without a mesh (DESIGN.md §Sharded cohorts).
        """
        local, vlocal = _jitted_local_steps(cfg)
        n = len(keys)
        if not parallel:
            client_trees, losses = [], []
            for i in range(n):
                t, l = local(tree, batches[i], keys[i], lr)
                client_trees.append(t)
                losses.append(l)
            return CohortBatch.from_list(client_trees, losses), None
        m = n if pad_to is None else pad_to
        keys_arr = keys if hasattr(keys, "shape") else jnp.stack(list(keys))
        if mesh is not None and mesh.size > 1:
            ext = 1
            for a in ("pod", "data"):
                if a in mesh.axis_names:
                    ext *= mesh.shape[a]
            m = -(-m // ext) * ext
            batches, keys_arr = _pad_cohort_inputs(batches, keys_arr, m)
            trees, losses = _jitted_sharded_steps(cfg, mesh)(
                tree, batches, keys_arr, lr)
            return CohortBatch.from_stacked(trees, losses, n=n), None
        batches, keys_arr = _pad_cohort_inputs(batches, keys_arr, m)
        trees, losses = vlocal(tree, batches, keys_arr, lr)
        return CohortBatch.from_stacked(trees, losses, n=n), None

    def finalize(self, cfg: FLConfig, client_state, aggregated_tree, uploads):
        return None


class FedCoClient:
    """FedCo baseline: MoCo with a *global* negative queue.

    Vehicles upload k-values alongside parameters; the RSU merges them
    into the global queue (`ssl.fedco_merge_queues`) and resets the key
    encoder to the aggregated model — exactly the protocol FLSimCo
    criticizes (Sec. 2: mixed-encoder negatives, representation leakage).
    """

    name = "fedco"

    def init_state(self, cfg: FLConfig, global_tree):
        queue = jax.random.normal(
            jax.random.PRNGKey(cfg.seed + 1),
            (cfg.queue_len, cfg.feature_dim))
        queue = queue / jnp.linalg.norm(queue, axis=-1, keepdims=True)
        return {"key_tree": jax.tree.map(jnp.copy, global_tree),
                "queue": queue}

    def run_cohort(self, cfg: FLConfig, tree, client_state, batches, keys,
                   lr, parallel: bool = True, pad_to: int | None = None,
                   mesh=None):
        # mesh accepted (uniform registry signature) and ignored:
        # sequential by design: the MoCo step threads a key-encoder EMA
        # whose updates are not batchable across clients — the result is
        # still stacked into a CohortBatch so aggregation sees one
        # uniform device-resident boundary (losses stay on device)
        moco = _jitted_moco_step(cfg)
        client_trees, losses, kvecs = [], [], []
        for i in range(len(keys)):
            t, _, kv, loss = moco(tree, client_state["key_tree"],
                                  client_state["queue"], batches[i],
                                  keys[i], lr)
            client_trees.append(t)
            losses.append(loss)
            kvecs.append(kv)
        return CohortBatch.from_list(client_trees, losses), kvecs

    def finalize(self, cfg: FLConfig, client_state, aggregated_tree, uploads):
        return {"key_tree": jax.tree.map(jnp.copy, aggregated_tree),
                "queue": ssl.fedco_merge_queues(client_state["queue"],
                                                uploads)}


CLIENT_UPDATES = {
    "dtssl": DTSSLClient(),
    "fedco": FedCoClient(),
}
