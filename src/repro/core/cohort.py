"""`CohortBatch` — the device-resident currency of a federated round.

Before this abstraction every layer boundary exchanged cohorts as Python
lists of per-client pytrees: the client layer unstacked its vmapped
result into N host-side trees (N `float(loss)` device syncs per round)
and the aggregation layer immediately re-stacked the same leaves before
the fused `wagg` kernel saw them. A `CohortBatch` keeps the cohort
stacked end to end:

  trees       pytree whose every leaf has a leading cohort axis (m, ...)
  losses      (m,) per-client mean local loss, device-resident
  mask        (m,) float32 validity; 1.0 for real clients, 0.0 padding
  n           static count of valid clients — valid rows are ALWAYS the
              prefix [0, n), padding (if any) the suffix [n, m)
  velocities  (m,) per-client velocities (attached by the topology)
  blur        (m,) Eq.-2 blur levels (attached by the topology)

The valid-prefix convention is load-bearing: `n` is a static Python int,
so `valid_*` views are static slices — aggregation weights are computed
on exactly the same values as an unpadded cohort, which is what makes
padded/masked aggregation bit-exact versus unpadded
(tests/test_cohort.py). Padding rows replicate the last valid row, so
they are always finite; masked weights zero them out of every sum.

Padding exists for the handover topology: per-RSU cohort sizes vary with
vehicle positions every round, and the vmapped cohort step specializes
on the cohort size. Bucketing each group up to the next power of two
(`bucket_size`) bounds the number of distinct compiled cohort-step sizes
by ceil(log2(vehicles_per_round)) + 1 while keeping every group on the
vmapped path (DESIGN.md §CohortBatch).

`CohortBatch` is registered as a jax pytree (with `n` static), so
`jax.device_get(cohort)` fetches losses + stats in one transfer and tree
ops map over the stacked leaves directly.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp


def bucket_size(n: int) -> int:
    """Smallest power of two >= n — the padded cohort sizes the vmapped
    client step compiles for (a bounded set; see module docstring)."""
    if n < 1:
        raise ValueError(f"cohort size must be >= 1, got {n}")
    m = 1
    while m < n:
        m *= 2
    return m


@dataclass(frozen=True)
class CohortBatch:
    """Stacked cohort state (leading axis = padded cohort size m)."""

    trees: Any
    losses: jnp.ndarray
    mask: jnp.ndarray
    n: int
    velocities: Optional[jnp.ndarray] = None
    blur: Optional[jnp.ndarray] = None

    # -- construction --------------------------------------------------------

    @classmethod
    def from_stacked(cls, trees, losses, n: Optional[int] = None,
                     **stats) -> "CohortBatch":
        """Wrap already-stacked leaves; rows [n, m) are padding."""
        m = int(losses.shape[0])
        n = m if n is None else int(n)
        if not 1 <= n <= m:
            raise ValueError(f"valid count {n} not in [1, {m}]")
        mask = (jnp.arange(m) < n).astype(jnp.float32)
        return cls(trees=trees, losses=losses, mask=mask, n=n, **stats)

    @classmethod
    def from_list(cls, trees: Sequence, losses, **stats) -> "CohortBatch":
        """Stack a list of per-client pytrees (the sequential reference
        path and legacy callers)."""
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *trees)
        losses = jnp.stack([jnp.asarray(l) for l in losses]) \
            if isinstance(losses, (list, tuple)) else jnp.asarray(losses)
        return cls.from_stacked(stacked, losses, n=len(trees), **stats)

    @classmethod
    def concat(cls, cohorts: Sequence["CohortBatch"]) -> "CohortBatch":
        """Concatenate the VALID rows of several cohorts (drops padding).

        Stats (velocities/blur) are concatenated when present on every
        input, else dropped.
        """
        trees = jax.tree.map(lambda *ls: jnp.concatenate(ls),
                             *[c.valid_trees for c in cohorts])
        losses = jnp.concatenate([c.valid_losses for c in cohorts])
        stats = {}
        for f in ("velocities", "blur"):
            vals = [getattr(c, f) for c in cohorts]
            if all(v is not None for v in vals):
                stats[f] = jnp.concatenate(
                    [v[:c.n] for v, c in zip(vals, cohorts)])
        return cls.from_stacked(trees, losses, **stats)

    # -- views ---------------------------------------------------------------

    @property
    def size(self) -> int:
        """Padded cohort size m (the stacked leading axis)."""
        return int(self.mask.shape[0])

    @property
    def valid_trees(self):
        """Stacked trees restricted to the n valid rows (static slice)."""
        if self.n == self.size:
            return self.trees
        return jax.tree.map(lambda x: x[:self.n], self.trees)

    @property
    def valid_losses(self):
        return self.losses[:self.n]

    @property
    def valid_blur(self):
        if self.blur is None:
            raise ValueError("cohort has no blur levels attached; the "
                             "topology must call with_stats() first")
        return self.blur[:self.n]

    @property
    def valid_velocities(self):
        if self.velocities is None:
            raise ValueError("cohort has no velocities attached; the "
                             "topology must call with_stats() first")
        return self.velocities[:self.n]

    def with_stats(self, velocities=None, blur=None) -> "CohortBatch":
        """Attach per-client velocities/blur, padded (by replicating the
        last value) to the cohort's padded size. Stats not passed keep
        their current value (incremental attachment never wipes)."""
        if velocities is None:
            velocities = self.velocities
        if blur is None:
            blur = self.blur

        def pad(x):
            if x is None:
                return None
            x = jnp.asarray(x)
            if x.shape[0] == self.size:
                return x
            if x.shape[0] != self.n:
                raise ValueError(f"stat length {x.shape[0]} matches "
                                 f"neither n={self.n} nor m={self.size}")
            reps = jnp.broadcast_to(x[-1:], (self.size - self.n,))
            return jnp.concatenate([x, reps])

        return dataclasses.replace(self, velocities=pad(velocities),
                                   blur=pad(blur))

    def take(self, idx) -> "CohortBatch":
        """Gather a sub-cohort by valid-row indices (device-side gather —
        the handover upload step regroups clients without unstacking).
        Gathers from the valid views, so padding rows are unreachable."""
        idx = jnp.asarray(idx)
        trees = jax.tree.map(lambda x: x[idx], self.valid_trees)
        pick = lambda x: None if x is None else x[:self.n][idx]
        return CohortBatch.from_stacked(
            trees, self.valid_losses[idx],
            velocities=pick(self.velocities), blur=pick(self.blur))

    # -- sharding (DESIGN.md §Sharded cohorts) -------------------------------

    @staticmethod
    def sharding_spec(mesh):
        """NamedSharding partitioning the leading cohort axis over the
        mesh's federated axes (("pod", "data") on a cohort mesh) — the
        one spec every sharded-cohort boundary uses."""
        from jax.sharding import NamedSharding, PartitionSpec
        axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
        # analysis: allow=retrace-ctor -- NamedSharding is a cheap value
        # object; the mesh (launch/mesh.py) is the cached state
        return NamedSharding(mesh, PartitionSpec(axes))

    def pad_to(self, m: int) -> "CohortBatch":
        """Re-pad the cohort to m rows by replicating the LAST row of
        every leaf (trees, losses, stats) — finite values, no RNG, and
        the mask still marks only the valid prefix [0, n), so every
        masked aggregation is bit-exact with the unpadded cohort (the
        same +0.0 argument as `padded_weights`)."""
        if m < self.size:
            raise ValueError(f"pad_to({m}) smaller than current padded "
                             f"size {self.size}")
        if m == self.size:
            return self
        pad = m - self.size

        def ext(x):
            if x is None:
                return None
            reps = jnp.broadcast_to(x[-1:], (pad,) + x.shape[1:])
            return jnp.concatenate([x, reps])

        return CohortBatch(trees=jax.tree.map(ext, self.trees),
                           losses=ext(self.losses),
                           mask=(jnp.arange(m) < self.n).astype(jnp.float32),
                           n=self.n, velocities=ext(self.velocities),
                           blur=ext(self.blur))

    def shard(self, mesh) -> "CohortBatch":
        """Place the cohort on `mesh` with the leading axis partitioned
        over the federated axes. Pads (replicated last row, masked out)
        up to the next multiple of the mesh's cohort extent first, so a
        cohort smaller than the mesh still shards — some devices then
        hold only padding rows, which zero weights make exact no-ops."""
        spec = self.sharding_spec(mesh)
        ext = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                ext *= mesh.shape[a]
        m = -(-self.size // ext) * ext
        return jax.device_put(self.pad_to(m), spec)

    def gather(self) -> "CohortBatch":
        """Undo `shard()`: the same cohort with every leaf resident on
        one device (device-side transfer, values untouched)."""
        return jax.device_put(self, jax.devices()[0])

    def padded_weights(self, w_valid) -> jnp.ndarray:
        """(n,) weights over the valid rows -> (m,) with zero padding.

        Weights are computed on the static valid slice and only then
        padded, so the padded weighted sum is bit-exact versus the
        unpadded one (appending zero-weight finite rows to a linear
        reduction adds exact +0.0 terms).
        """
        w = jnp.asarray(w_valid, jnp.float32).reshape(-1)
        if w.shape[0] != self.n:
            raise ValueError(f"got {w.shape[0]} weights for {self.n} "
                             f"valid clients")
        if self.size == self.n:
            return w
        return jnp.concatenate(
            [w, jnp.zeros((self.size - self.n,), jnp.float32)])

    # -- back-compat ---------------------------------------------------------

    def unstack(self) -> list:
        """Materialize the n valid per-client trees as a Python list.

        Kept only for legacy/reference consumers — the round engine never
        calls this; it is the old list-of-pytrees boundary this type
        replaces.
        """
        return [jax.tree.map(lambda x: x[i], self.trees)
                for i in range(self.n)]


def _flatten(c: CohortBatch):
    children = (c.trees, c.losses, c.mask, c.velocities, c.blur)
    return children, c.n


def _unflatten(n, children):
    trees, losses, mask, velocities, blur = children
    return CohortBatch(trees=trees, losses=losses, mask=mask, n=n,
                       velocities=velocities, blur=blur)


jax.tree_util.register_pytree_node(CohortBatch, _flatten, _unflatten)
