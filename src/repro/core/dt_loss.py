"""Dual-Temperature (DT) contrastive loss — FLSimCo Eq. (6)-(8).

SimCo (Zhang et al., arXiv:2203.17248) removes MoCo's queue + momentum
encoder by splitting the temperature's two roles:

  * tau_alpha shapes the *intra-anchor* distribution (the softmax actually
    trained through),
  * tau_beta shapes the *inter-anchor* hardness weight.

Per anchor i:   L_i = -sg[ W_beta_i / W_alpha_i ] * log p_alpha_i(pos)
with            W_tau_i = 1 - softmax_tau(logits_i)[pos].

The stop-gradient ratio reproduces the hardness-awareness a large MoCo
dictionary provides, without storing one — the paper's reason SimCo fits
vehicle-grade hardware.

`dt_loss_matrix` is the faithful in-batch form used by FLSimCo: anchors
q_i = f(pi1(x_i)), positives k_i = f(pi2(x_i)), negatives k_j (j != i)
(Eq. 3-5). A Pallas-fused version lives in repro.kernels.dt_loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

DEFAULT_TAU_ALPHA = 0.1
DEFAULT_TAU_BETA = 1.0


def _dt_from_logits(logits, pos_index, tau_alpha, tau_beta):
    """logits: (B, 1+K) raw similarities (pos at column `pos_index`).

    Returns per-anchor loss vector (B,).
    """
    la = logits / tau_alpha
    lb = logits / tau_beta
    log_pa = jax.nn.log_softmax(la, axis=-1)
    pa = jnp.exp(log_pa)
    pb = jax.nn.softmax(lb, axis=-1)
    pos_a = jnp.take_along_axis(pa, pos_index[:, None], axis=-1)[:, 0]
    pos_b = jnp.take_along_axis(pb, pos_index[:, None], axis=-1)[:, 0]
    w_alpha = 1.0 - pos_a                                    # Eq. (8)
    w_beta = 1.0 - pos_b                                     # Eq. (7)
    weight = jax.lax.stop_gradient(w_beta / jnp.maximum(w_alpha, 1e-8))
    log_pos_a = jnp.take_along_axis(log_pa, pos_index[:, None], axis=-1)[:, 0]
    return -weight * log_pos_a                               # Eq. (6)


def dt_loss(q, k_pos, k_neg, tau_alpha=DEFAULT_TAU_ALPHA,
            tau_beta=DEFAULT_TAU_BETA):
    """Explicit-negative form. q,k_pos: (B,D); k_neg: (K,D) shared negatives."""
    pos = jnp.sum(q * k_pos, axis=-1, keepdims=True)         # (B,1)
    neg = q @ k_neg.T                                        # (B,K)
    logits = jnp.concatenate([pos, neg], axis=-1).astype(jnp.float32)
    pos_index = jnp.zeros((q.shape[0],), jnp.int32)
    return _dt_from_logits(logits, pos_index, tau_alpha, tau_beta).mean()


def dt_loss_matrix(q, k, tau_alpha=DEFAULT_TAU_ALPHA, tau_beta=DEFAULT_TAU_BETA):
    """In-batch form (FLSimCo Eq. 3-5): positives on the diagonal of q@k^T,
    negatives are the other columns. q, k: (B, D), L2-normalized."""
    B = q.shape[0]
    sim = (q @ k.T).astype(jnp.float32)                      # (B,B)
    pos_index = jnp.arange(B, dtype=jnp.int32)
    return _dt_from_logits(sim, pos_index, tau_alpha, tau_beta).mean()


def info_nce_loss(q, k_pos, queue, tau=0.07):
    """MoCo-style InfoNCE against a negative queue — FedCo baseline."""
    pos = jnp.sum(q * k_pos, axis=-1, keepdims=True)
    neg = q @ queue.T
    logits = jnp.concatenate([pos, neg], axis=-1).astype(jnp.float32) / tau
    return -jax.nn.log_softmax(logits, axis=-1)[:, 0].mean()
