"""Whole-campaign compilation — many rounds per XLA dispatch.

The eager loop (`run_round`, core/topology.py) re-enters Python every
round: host-RNG draws, eager blur/aggregation dispatches, a per-round
`device_get`. All of that is *training-independent* — cohort ids and
batch indices come from the MT19937 host stream, velocities/keys from
the jax PRNG chain, the LR from a pure function of the round index, and
(for handover) motion, grouping, upload weights and sync decisions are
functions of those draws alone. So a campaign of K rounds factors into

  plan    — replay the EXACT eager draw sequence K rounds ahead into
            device schedule arrays (ids, batch indices, client keys,
            velocities, blur, lr, and for handover: download RSU per
            client, zero-padded upload-weight matrices, sync flags +
            level-2 weights). The plan consumes the host RNG and jax
            key chain identically to the eager loop, bit for bit —
            the draw helpers (`_cohort_plan`, `_batch_indices`,
            `HandoverMultiRSU.plan_round`) are shared verbatim.
  execute — a single jitted round body applied K times, either as a
            python loop over one compiled program (mode="jit") or as
            `jax.lax.scan` chunks (mode="scan"). History (per-client
            losses) streams out device-side and is fetched ONCE per
            chunk; records are assembled on host afterwards.

Two modes because of a backend asymmetry: `lax.scan` lowers to a while
loop, and XLA-CPU pessimizes convolutions inside while loops (~25x;
the same issue keeps `local_iters` python-unrolled in core/clients.py).
On CPU the scan EXECUTES slower than the eager loop; a python loop over
one fully-jitted round keeps the fusion win without the while loop.
mode="auto" therefore picks "jit" on the CPU backend and "scan"
elsewhere. Both modes are chunk-composable bit for bit:

  * "jit" applies the SAME compiled program round by round, so any
    pause/checkpoint/resume split replays identical programs;
  * "scan" chunks compose exactly — scan(a)+scan(b) == scan(a+b) and
    K x scan(1) == scan(K), verified leafwise in tests/test_engine.py
    (the carry crosses chunk boundaries as device values, and
    `optimization_barrier` pinch points keep XLA from fusing across
    the aggregation boundary differently per chunk length).

Versus the eager loop, the ENTIRE schedule (cohort ids, batch indices,
velocities, blur levels, LR, key chain, host-RNG successor state,
positions, upload weights, sync decisions — every record field except
the loss) is bitwise-identical. The fused round body itself reassociates
the client-step/aggregation arithmetic, so model trees and losses agree
only to float tolerance across engines (and across the two modes) —
this is inherent to XLA, not a looseness of this module: even the
UNCHANGED legacy step evaluated eagerly vs jitted differs in its f32
loss, and SSL training chaotically compounds such deltas over rounds
(tiny-batch BatchNorm amplifies them further at toy sizes). The
enforceable contract is therefore: schedule bitwise vs eager, the
client step itself bitwise vs the legacy jitted cohort step, and
EVERYTHING bitwise WITHIN a mode — any chunking, any save/restore
split. tests/test_engine.py enforces each layer.

Compile bound: one program per (mode, topology, shape) — mode="jit"
compiles exactly one round body per campaign; mode="scan" one program
per distinct chunk length (<= 2 for a fixed checkpoint cadence: the
body chunk + the remainder). The handover topology needs NO extra
programs and no eager fallback: instead of per-download-group cohorts
(whose sizes change with vehicle motion), the compiled body gathers
each client's init model from the stacked per-RSU carry
(`rsu_stack[down[i]]`) and applies uploads as zero-padded weight
matrices under `where`-gated sync — regrouping changes DATA, never
shapes, so one program covers every round regime.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.codecs import CODECS
from repro.core import aggregation as agg
from repro.core.clients import raw_local_step
from repro.core.cohort import CohortBatch
from repro.core.hierarchical import aggregate_hierarchical
from repro.core.mobility import apply_motion_blur
from repro.core.state import FLState, pack_host_rng, unpack_host_rng
from repro.core.topology import (HandoverMultiRSU, MultiRSU, SingleRSU,
                                 _batch_indices, _cohort_plan)

MODES = ("auto", "jit", "scan")


# --------------------------------------------------------------------------
# support checks
# --------------------------------------------------------------------------

def check_campaign_supported(scenario) -> None:
    """Fail fast (before any compile) on configs the compiled engine
    cannot express."""
    cfg, topo = scenario.cfg, scenario.topology
    if cfg.client != "dtssl":
        raise ValueError(
            "run_campaign compiles the whole round into one traced body, "
            "which requires a stateless, vmappable client update; "
            f"client={cfg.client!r} is sequential (FedCo threads a MoCo "
            "key-encoder/queue through the cohort). Use the eager "
            "run()/run_round() loop for it.")
    if type(topo) is MultiRSU:
        # resolves the cohort mesh the compiled body will trace with —
        # raises the actionable mesh_aggregate errors (uneven cohorts,
        # missing devices) before any compile
        topo.resolve_mesh(cfg)
    if type(topo) not in (SingleRSU, MultiRSU, HandoverMultiRSU):
        raise ValueError(
            f"run_campaign supports the built-in topologies "
            f"(single/multi/handover); got {type(topo).__name__}. "
            "Custom topologies run through the eager run() loop.")


def resolve_mode(mode: str) -> str:
    """auto -> "jit" on the CPU backend (scan's while loop pessimizes
    convolutions there), "scan" on accelerators."""
    if mode not in MODES:
        raise ValueError(f"mode {mode!r} not in {MODES}")
    if mode != "auto":
        return mode
    return "jit" if jax.default_backend() == "cpu" else "scan"


# --------------------------------------------------------------------------
# schedule planning (replays the eager draw sequence, bit for bit)
# --------------------------------------------------------------------------

def _data_stack(scenario):
    """Per-client data as one device array (n_clients, maxlen, ...);
    rows are zero-padded to the longest client but padding is never
    indexed (batch indices are drawn against each client's true
    length, exactly like the eager path)."""
    data = scenario.data
    sample = np.asarray(data[0])
    maxlen = max(len(d) for d in data)
    stack = np.zeros((len(data), maxlen) + sample.shape[1:], sample.dtype)
    for c, d in enumerate(data):
        stack[c, : len(d)] = d
    return jnp.asarray(stack)


def _plan_cohort_chunk(state, scenario, k: int):
    """Schedule for k single/multi rounds. Returns (xs_list, recs, key,
    rng) with the host RNG and jax key advanced exactly as k eager
    rounds would advance them."""
    cfg, mob, topo = scenario.cfg, scenario.mobility, scenario.topology
    rng = unpack_host_rng(state.host_rng)
    key = state.key
    if type(topo) is MultiRSU:
        assign = np.arange(cfg.vehicles_per_round) % topo.n_rsus
        # analysis: allow=host-sync-cast -- assign is host numpy
        rsu_sizes = [int((assign == r).sum()) for r in range(topo.n_rsus)
                     if (assign == r).any()]
    xs_list, recs = [], []
    for i in range(k):
        rnd = state.round + i
        ids, velocities, lr, key, cks = _cohort_plan(rng, key, rnd, scenario)
        idx = np.stack([_batch_indices(rng, len(scenario.data[c]), cfg)
                        for c in ids])
        blur = mob.blur_level(velocities)
        # analysis: allow=retrace-fresh-array -- the once-per-round
        # schedule upload: fresh host draws become device xs here
        xs_list.append((jnp.asarray(ids.astype(np.int32)),
                        jnp.asarray(idx.astype(np.int32)),
                        jnp.stack(cks), velocities, blur, lr))
        # analysis: sanctioned-sync -- plan-time record build: one
        # O(cohort) fetch per planned round, off the compiled path
        rec = {"round": rnd, "loss": None,
               "velocities": np.asarray(velocities).tolist(),
               "lr": float(lr), "topology": topo.name}
        if type(topo) is MultiRSU:
            rec["rsu_sizes"] = list(rsu_sizes)
        recs.append(rec)
    return xs_list, recs, key, rng


def _plan_handover_chunk(state, scenario, k: int):
    """Schedule for k handover rounds: replays `plan_round` (the SAME
    code the eager round executes) and packs each plan into device
    arrays. Returns (xs_list, recs, key, rng, topo_host) where
    topo_host carries the advanced positions/accumulators."""
    topo = scenario.topology
    R = topo.n_rsus
    n = scenario.cfg.vehicles_per_round
    rng = unpack_host_rng(state.host_rng)
    key = state.key
    # analysis: allow=host-sync-fetch -- handover topo state is host
    # numpy (positions/accumulators); copies keep planning pure
    positions = np.asarray(state.topo["positions"])
    # analysis: allow=host-sync-fetch -- host accumulator copy
    blur_sum = np.array(state.topo["blur_sum"], np.float64)
    # analysis: allow=host-sync-fetch -- host accumulator copy
    upload_count = np.array(state.topo["upload_count"], np.float64)
    xs_list, recs = [], []
    for i in range(k):
        rnd = state.round + i
        plan = topo.plan_round(rng, key, rnd, positions, blur_sum,
                               upload_count, scenario)
        key = plan["key"]
        positions = plan["positions"]
        blur_sum, upload_count = plan["blur_sum"], plan["upload_count"]
        wmat = np.zeros((R, n), np.float32)
        has_up = np.zeros((R,), bool)
        for rsu, sel, w in plan["uploads"]:
            wmat[rsu, sel] = w
            has_up[rsu] = True
        sync_w = (plan["sync_W"] if plan["synced"]
                  else np.zeros((R,), np.float64)).astype(np.float32)
        # analysis: allow=retrace-fresh-array -- the once-per-round
        # schedule upload (handover plan arrays become device xs)
        xs_list.append((jnp.asarray(plan["ids"].astype(np.int32)),
                        jnp.asarray(plan["idx"].astype(np.int32)),
                        jnp.stack(plan["cks"]), plan["velocities"],
                        plan["lr"],
                        jnp.asarray(plan["down"].astype(np.int32)),
                        jnp.asarray(wmat), jnp.asarray(has_up),
                        jnp.asarray(bool(plan["synced"])),
                        jnp.asarray(sync_w)))
        # analysis: sanctioned-sync -- plan-time record build;
        # stale/velocities are host plan arrays
        recs.append({"round": rnd, "loss": None,
                     "velocities": np.asarray(plan["velocities"]).tolist(),
                     "lr": float(plan["lr"]), "topology": topo.name,
                     "rsu_sizes": plan["upload_sizes"],
                     "n_handovers": int(plan["stale"].sum()),
                     "synced": plan["synced"]})
    topo_host = {"positions": positions, "blur_sum": blur_sum,
                 "upload_count": upload_count}
    return xs_list, recs, key, rng, topo_host


# --------------------------------------------------------------------------
# round bodies (one per topology family)
# --------------------------------------------------------------------------

def _round_codec(cfg):
    """The codec the compiled bodies thread, or None for identity (the
    no-op stage costs nothing to skip at trace time). A stateful codec
    grows the carry by its error-feedback residual — still ONE traced
    program per campaign (`compile_counts`): the codec is part of the
    cfg in the callable cache key, and its ops trace into the same
    round body."""
    return None if cfg.codec == "identity" else CODECS[cfg.codec]


def _client_batches(dstack, ids, idx, velocities, scenario):
    batches = dstack[ids[:, None], idx]
    if scenario.blur_images:
        batches = jax.vmap(apply_motion_blur, in_axes=(0, 0, None))(
            batches, velocities, scenario.mobility.camera_const)
    return batches


def _build_cohort_body(scenario):
    """Round body for SingleRSU / MultiRSU: carry = (global_tree,).

    When MultiRSU resolves a multi-device cohort mesh (the default with
    >1 device — see `MultiRSU.resolve_mesh`), the traced body runs the
    client blocks under shard_map and routes the two-level reduction
    through `sharded_hierarchical` — the compiled path and the sharded
    path COMPOSE (shard_map inlines into the jitted round program), one
    program per campaign either way.
    """
    cfg, topo = scenario.cfg, scenario.topology
    local = raw_local_step(cfg)
    mesh = None
    if type(topo) is MultiRSU:
        assign = np.arange(cfg.vehicles_per_round) % topo.n_rsus
        sels = [np.where(assign == r)[0] for r in range(topo.n_rsus)]
        sels = [s for s in sels if s.size]
        count_scaled = topo.count_scaled
        mesh = topo.resolve_mesh(cfg)
        if mesh is not None and mesh.size > 1:
            from jax.sharding import PartitionSpec as P

            from repro.compat import shard_map
            from repro.core.hierarchical import sharded_hierarchical
            axes = tuple(a for a in mesh.axis_names
                         if a in ("pod", "data"))
            # rsu-major permutation: client blocks shard in cohort order
            # (losses stream out in cohort order, same as the host body),
            # the reduction sees rsu-major rows
            perm = np.concatenate(sels)
            # analysis: allow=retrace-ctor -- built once per campaign
            # callable, memoized in _CALLABLE_CACHE below
            sh_step = shard_map(
                jax.vmap(local, in_axes=(None, 0, 0, None)), mesh=mesh,
                in_specs=(P(), P(axes), P(axes), P()),
                out_specs=(P(axes), P(axes)), check=False)
        else:
            mesh = None
    aggregator = agg.AGGREGATORS[cfg.aggregator]
    codec = _round_codec(cfg)

    def body(dstack, carry, xs):
        if codec is not None and codec.stateful:
            tree, ef = carry
        else:
            (tree,) = carry
            ef = None
        ids, idx, cks, velocities, blur, lr = xs
        batches = _client_batches(dstack, ids, idx, velocities, scenario)
        if mesh is not None:
            trees, losses = sh_step(tree, batches, cks, lr)
        else:
            trees, losses = jax.vmap(local, in_axes=(None, 0, 0, None))(
                tree, batches, cks, lr)
        trees, losses, blur = jax.lax.optimization_barrier(
            (trees, losses, blur))
        new_ef = None
        if codec is not None:
            # comms tier, in cohort order (EF slot i = cohort position
            # i — identical to the host paths' rows=sel/perm scatter);
            # the aggregation below consumes the RECONSTRUCTED trees
            payload, new_ef = codec.encode(trees, tree, ef)
            trees = codec.decode(payload, tree)
        if mesh is not None:
            new_tree = sharded_hierarchical(
                jax.tree.map(lambda x: x[perm], trees), blur[perm], mesh,
                len(sels), count_scaled=count_scaled,
                reduction=topo.mesh_reduction)
        elif type(topo) is MultiRSU:
            cohorts = [
                CohortBatch.from_stacked(
                    jax.tree.map(lambda x: x[sel], trees), losses[sel]
                ).with_stats(velocities=velocities[sel], blur=blur[sel])
                for sel in sels]
            new_tree = aggregate_hierarchical(cohorts,
                                              count_scaled=count_scaled)
        else:
            cohort = CohortBatch.from_stacked(trees, losses).with_stats(
                velocities=velocities, blur=blur)
            new_tree = aggregator(cohort, cfg)
        if new_ef is not None:
            new_tree, new_ef = jax.lax.optimization_barrier(
                (new_tree, new_ef))
            return (new_tree, new_ef), losses
        new_tree = jax.lax.optimization_barrier(new_tree)
        return (new_tree,), losses

    return body


def _build_handover_body(scenario):
    """Round body for HandoverMultiRSU: carry = (global_tree, rsu_stack)
    where rsu_stack holds the per-RSU models with a leading n_rsus axis.

    Every download/upload regrouping arrives as DATA (the per-client
    download index, the zero-padded upload-weight matrix, the sync flag
    + level-2 weights), so one compiled program covers every round —
    no bucket regimes, no eager fallback. Zero upload weights contribute
    exact +0.0 terms and `where`-gated sync/keep branches select full
    precomputed alternatives, matching the eager skip/sync semantics.
    """
    cfg, topo = scenario.cfg, scenario.topology
    R = topo.n_rsus
    local = raw_local_step(cfg)
    codec = _round_codec(cfg)

    def body(dstack, carry, xs):
        if codec is not None and codec.stateful:
            gtree, rstack, ef = carry
        else:
            gtree, rstack = carry
            ef = None
        ids, idx, cks, velocities, lr, down, wmat, has_up, sync, sync_w = xs
        batches = _client_batches(dstack, ids, idx, velocities, scenario)
        # each client trains from the model of the RSU covering its
        # round-start position — a gather out of the stacked carry
        init_trees = jax.tree.map(lambda x: x[down], rstack)
        trees, losses = jax.vmap(local, in_axes=(0, 0, 0, None))(
            init_trees, batches, cks, lr)
        trees, losses = jax.lax.optimization_barrier((trees, losses))
        new_ef = None
        if codec is not None:
            # comms tier: each client's delta is against its DOWNLOAD
            # RSU's model (a per-row stacked base), matching the eager
            # handover path's per-group roundtrip
            payload, new_ef = codec.encode(trees, init_trees, ef,
                                           stacked_base=True)
            trees = codec.decode(payload, init_trees, stacked_base=True)
        # uploads: each RSU's new model is a weighted sum over the FULL
        # cohort with zero weights off-group; RSUs without usable
        # uploads keep their model
        ups = [agg._weighted_stacked_sum(trees, wmat[r]) for r in range(R)]
        up_stack = jax.tree.map(lambda *ls: jnp.stack(ls), *ups)

        def keep(old, new):
            sel = has_up.reshape((R,) + (1,) * (old.ndim - 1))
            return jnp.where(sel, new, old)

        rstack = jax.tree.map(keep, rstack, up_stack)
        # region sync: merge with the precomputed level-2 weights when
        # the flag is set, else pass both models through unchanged
        merged = agg._weighted_stacked_sum(rstack, sync_w)
        rstack = jax.tree.map(
            lambda r_, m: jnp.where(sync, jnp.broadcast_to(m, r_.shape), r_),
            rstack, merged)
        gtree = jax.tree.map(lambda g, m: jnp.where(sync, m, g),
                             gtree, merged)
        if new_ef is not None:
            gtree, rstack, new_ef = jax.lax.optimization_barrier(
                (gtree, rstack, new_ef))
            return (gtree, rstack, new_ef), losses
        gtree, rstack = jax.lax.optimization_barrier((gtree, rstack))
        return (gtree, rstack), losses

    return body


# --------------------------------------------------------------------------
# compiled-callable cache
# --------------------------------------------------------------------------

_CALLABLE_CACHE: dict = {}


def _campaign_key(scenario):
    return (scenario.cfg,
            tuple(sorted(scenario.topology.signature().items())),
            scenario.mobility, scenario.blur_images,
            agg._resolve_wagg_backend())


def campaign_callables(scenario) -> dict:
    """The jitted round body + scan wrapper for this scenario, cached on
    (cfg, topology signature, mobility, blur flag, wagg backend) — a
    sweep over seeds/rounds reuses one compilation; switching the wagg
    backend retraces. The data stack is an ARGUMENT, so programs
    specialize on shapes only, never on dataset values."""
    key = _campaign_key(scenario)
    got = _CALLABLE_CACHE.get(key)
    if got is None:
        if isinstance(scenario.topology, HandoverMultiRSU):
            body = _build_handover_body(scenario)
        else:
            body = _build_cohort_body(scenario)
        # trace counters: jax runs the python function once per trace,
        # and every trace lowers to exactly one XLA program — unlike
        # `fn._cache_size()`, which also counts dispatch-cache re-keys
        # for equivalent inputs (e.g. numpy leaves from a restored
        # checkpoint) that reuse the existing executable
        traces = {"jit_round": 0, "scan": 0}

        def _counted(name, f):
            def wrapped(*a):
                traces[name] += 1
                return f(*a)
            return wrapped

        def _scan(ds, c, xs):
            return jax.lax.scan(lambda cc, x: body(ds, cc, x), c, xs)

        got = {
            # analysis: allow=retrace-ctor -- memoized in _CALLABLE_CACHE
            "jit_round": jax.jit(_counted("jit_round", body)),
            # analysis: allow=retrace-ctor -- memoized in _CALLABLE_CACHE
            "scan": jax.jit(_counted("scan", _scan)),
            "traces": traces,
        }
        _CALLABLE_CACHE[key] = got
    return got


def compile_counts(scenario) -> dict:
    """Traced-program counts for this scenario's engine callables:
    {"jit_round": ..., "scan": ...} (each trace lowers to one XLA
    compile). The campaign contract — benchmarks/round_engine.py
    asserts it — is jit_round <= 1 program per campaign and scan <=
    #distinct chunk lengths (<= 2 for a fixed checkpoint cadence),
    REGARDLESS of topology: handover regrouping is data, not shape."""
    got = _CALLABLE_CACHE.get(_campaign_key(scenario))
    if got is None:
        return {"jit_round": 0, "scan": 0}
    return dict(got["traces"])


def reset_engine_caches() -> None:
    """Drop every cached engine callable (benchmark/test isolation)."""
    _CALLABLE_CACHE.clear()


# --------------------------------------------------------------------------
# campaign driver
# --------------------------------------------------------------------------

def _carry_of(state, scenario):
    codec = _round_codec(scenario.cfg)
    # a stateful codec's error-feedback residual rides in the carry so
    # the compiled chunks thread it exactly like the eager rounds do
    ef = (state.comms["ef"],) if codec is not None and codec.stateful else ()
    if isinstance(scenario.topology, HandoverMultiRSU):
        rstack = jax.tree.map(lambda *ls: jnp.stack(ls),
                              *state.topo["rsu_models"])
        return (state.global_tree, rstack) + ef
    return (state.global_tree,) + ef


def _state_of(carry, state, scenario, key, rng, k, topo_host):
    codec = _round_codec(scenario.cfg)
    comms = state.comms
    if codec is not None and codec.stateful:
        carry, comms = carry[:-1], {"ef": carry[-1]}
    if isinstance(scenario.topology, HandoverMultiRSU):
        gtree, rstack = carry
        R = scenario.topology.n_rsus
        topo = {"positions": topo_host["positions"],
                "rsu_models": tuple(
                    jax.tree.map(lambda x: x[r], rstack) for r in range(R)),
                "blur_sum": topo_host["blur_sum"],
                "upload_count": topo_host["upload_count"]}
        return state.replace(global_tree=gtree, key=key,
                             host_rng=pack_host_rng(rng),
                             round=state.round + k, topo=topo, comms=comms)
    return state.replace(global_tree=carry[0], key=key,
                         host_rng=pack_host_rng(rng),
                         round=state.round + k, comms=comms)


def _plan_chunk(state, scenario, k):
    if isinstance(scenario.topology, HandoverMultiRSU):
        return _plan_handover_chunk(state, scenario, k)
    xs_list, recs, key, rng = _plan_cohort_chunk(state, scenario, k)
    return xs_list, recs, key, rng, {}


def run_campaign(scenario, state: Optional[FLState] = None,
                 rounds: Optional[int] = None, *, mode: str = "auto",
                 checkpoint_every: Optional[int] = None,
                 checkpoint_dir: Optional[str] = None,
                 log_every: int = 0, transfer_guard: bool = False,
                 publish=None, publish_every: int = 0):
    """Run `rounds` rounds (default cfg.rounds) through the compiled
    campaign engine. Returns (final state, history) like `run`, with the
    whole schedule bitwise-identical to the eager loop (losses/models
    agree to float tolerance; see the module docstring).

    mode              "jit" (one compiled round, python loop — the CPU
                      fast path), "scan" (lax.scan chunks — the
                      accelerator path), or "auto" (pick by backend)
    checkpoint_every  chunk size AND checkpoint cadence; resuming from
                      any saved chunk boundary is bit-exact with the
                      uninterrupted campaign (tests/test_engine.py)
    checkpoint_dir    where `save_state` writes round_NNNNNN.npz (+ the
                      scenario fingerprint sidecar); required when
                      checkpoint_every is set
    log_every         print the same "[round N] loss=... lr=..." lines
                      as the eager `run`, but from the ONCE-per-chunk
                      fetched history — logging never adds a per-round
                      host sync to the compiled path
    transfer_guard    wrap the fused-round dispatch (not the host-side
                      planning) in `analysis.guards.no_implicit_transfers`
                      so any implicit host<->device transfer inside the
                      compiled path raises. Steady-state assertion: run
                      one warm-up campaign first — compilation itself
                      uploads constants and would trip the guard
                      (tests/test_engine.py::test_round_body_no_implicit_transfers)
    publish           serving hook: called as ``publish(round, tree)``
                      with the post-chunk ``FLState`` round and global
                      tree (device arrays, untouched) at the SAME
                      once-per-chunk boundary as the history fetch —
                      e.g. ``ModelStore.publish`` from repro.serve.
                      Serving never adds per-round device syncs
                      (tests/test_serve.py pins the compile bounds)
    publish_every     chunk size when only serving cadence matters —
                      like log_every/checkpoint_every but for the
                      publish hook; 0 publishes once per natural chunk
    """
    check_campaign_supported(scenario)
    mode = resolve_mode(mode)
    if checkpoint_every is not None:
        if checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        if checkpoint_dir is None:
            raise ValueError("checkpoint_every needs checkpoint_dir")
    if state is None:
        state = scenario.init_state()
    total = rounds if rounds is not None else scenario.cfg.rounds
    if publish_every < 0:
        raise ValueError("publish_every must be >= 0")
    chunk = (checkpoint_every or publish_every
             or (log_every if log_every > 0 else total))
    chunk = max(1, min(chunk, total)) if total else 1
    fns = campaign_callables(scenario)
    dstack = _data_stack(scenario)
    history = []
    done = 0
    while done < total:
        k = min(chunk, total - done)
        xs_list, recs, key, rng, topo_host = _plan_chunk(state, scenario, k)
        carry = _carry_of(state, scenario)
        if transfer_guard:
            from repro.analysis.guards import no_implicit_transfers
            guard = no_implicit_transfers()
        else:
            guard = contextlib.nullcontext()
        with guard:
            if mode == "scan":
                xs = jax.tree.map(lambda *ls: jnp.stack(ls), *xs_list)
                carry, ys = fns["scan"](dstack, carry, xs)
            else:
                ys = []
                for x in xs_list:
                    carry, losses = fns["jit_round"](dstack, carry, x)
                    ys.append(losses)
                ys = jnp.stack(ys)
        # ONE host transfer per chunk: the stacked loss history
        # analysis: sanctioned-sync -- the designed once-per-chunk fetch
        losses_h = np.asarray(jax.device_get(ys), np.float64)
        for i, rec in enumerate(recs):
            rec["loss"] = float(np.mean(losses_h[i]))
            history.append(rec)
            if log_every and rec["round"] % log_every == 0:
                print(f"[round {rec['round']:4d}] loss={rec['loss']:.4f} "
                      f"lr={rec['lr']:.4f}")
        state = _state_of(carry, state, scenario, key, rng, k, topo_host)
        if publish is not None:
            publish(state.round, state.global_tree)
        done += k
        if checkpoint_every:
            from repro.checkpoint.store import save_state
            save_state(os.path.join(checkpoint_dir,
                                    f"round_{state.round:06d}"),
                       state, scenario)
    return state, history
