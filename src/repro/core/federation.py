"""Federated orchestration — FLSimCo Sec. 4 Steps 1-4.

One `FederatedTrainer` drives the full loop of the paper:

  Step 1  RSU initializes the global model
  Step 2  each participating vehicle downloads it, applies pi1/pi2 to its
          local (velocity-blurred) images, and runs `local_iters` SGD steps
          on the dual-temperature loss
  Step 3  vehicles upload parameters + velocity
  Step 4  the RSU aggregates with the selected scheme (see the
          ``AGGREGATORS`` registry in core/aggregation.py: flsimco /
          fedavg / discard / softmax / inverse, plus the trainer-handled
          fedco) and the next round begins

The *shape* of a round — how many RSUs there are, which vehicles talk to
which RSU, and how RSU models merge — is delegated to a pluggable
`Topology` (core/topology.py): `SingleRSU` (paper-exact, the default),
`MultiRSU` (hierarchical two-level Eq. 11), and `HandoverMultiRSU`
(vehicles migrate between RSU coverage ranges mid-training). The trainer
keeps the client-side machinery: sampling, batching, blur, and the local
SGD steps.

Clients within a cohort are executed with ``jax.vmap`` over a stacked
parameter tree — the same "cohorts in parallel" dataflow the production
mesh uses (launch/steps.py), just with the batch axis instead of mesh
axes. A sequential python path is kept for readability/debugging and is
tested equivalent.

Supports both the paper's ResNet backbone (images) and any token
architecture from the zoo (token views), per DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import aggregation as agg
from repro.core import ssl
from repro.core.dt_loss import dt_loss_matrix, info_nce_loss
from repro.core.mobility import KMH_100, MobilityModel, apply_motion_blur
from repro.core.topology import SingleRSU, Topology
from repro.models.resnet import resnet_apply
from repro.optim.optimizers import cosine_schedule, sgd


@dataclass(frozen=True)
class FLConfig:
    n_vehicles: int = 95          # fleet size (Table 1)
    vehicles_per_round: int = 5   # N_r (Fig. 5: 5 or 10)
    local_iters: int = 1          # local SGD iterations per round
    batch_size: int = 512         # Table 1 / Sec. 5.2
    rounds: int = 150             # R^max
    lr: float = 0.9               # Table 1 (cosine annealed)
    momentum: float = 0.9
    weight_decay: float = 5e-4
    tau_alpha: float = 0.1
    tau_beta: float = 1.0
    aggregator: str = "flsimco"   # any AGGREGATORS name (core/aggregation.py)
                                  # or "fedco" (trainer-handled baseline)
    blur_threshold: float = KMH_100
    moco_momentum: float = 0.99   # FedCo key-encoder EMA (Table 1)
    queue_len: int = 4096         # FedCo global queue (Sec. 5.2)
    feature_dim: int = 128
    normalize_weights: bool = True
    seed: int = 0


# --------------------------------------------------------------------------
# per-client local training (ResNet / images)
# --------------------------------------------------------------------------

def _client_loss(tree, cfg: FLConfig, images, key):
    """pi1/pi2 views -> encoder -> DT loss. Returns (loss, new_tree)."""
    k1, k2 = jax.random.split(key)
    v1 = ssl.pi1(k1, images)
    v2 = ssl.pi2(k2, images)
    q, _, tree1 = resnet_apply(tree, v1, train=True)
    k, _, tree2 = resnet_apply(tree1, v2, train=True)
    loss = dt_loss_matrix(q, k, cfg.tau_alpha, cfg.tau_beta)
    return loss, tree2


def make_local_train_step(cfg: FLConfig):
    opt_init, opt_update = sgd(cfg.momentum, cfg.weight_decay)

    def local_train(tree, images, key, lr):
        """cfg.local_iters SGD steps on one client. Returns (tree, loss).

        The iteration loop is a *python* unroll, not lax.scan: XLA-CPU
        pessimizes convolutions inside while-loops (~25x slower measured),
        and local_iters is 1-2 in the paper.
        """
        opt_state = opt_init(tree["params"])
        losses = []
        for k in jax.random.split(key, cfg.local_iters):
            tree_c = tree

            def loss_fn(params):
                t = {"params": params, "state": tree_c["state"]}
                loss, t2 = _client_loss(t, cfg, images, k)
                return loss, t2["state"]

            (loss, new_state), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(tree_c["params"])
            new_params, opt_state = opt_update(tree_c["params"], grads,
                                               opt_state, lr)
            tree = {"params": new_params, "state": new_state}
            losses.append(loss)
        return tree, jnp.stack(losses).mean()

    return local_train


def make_moco_local_train_step(cfg: FLConfig):
    """FedCo client: InfoNCE against the (global) queue, EMA key encoder."""
    opt_init, opt_update = sgd(cfg.momentum, cfg.weight_decay)

    def local_train(tree, key_tree, queue, images, key, lr):
        # python unroll (see make_local_train_step for the XLA-CPU rationale)
        opt_state = opt_init(tree["params"])
        losses, kvec = [], None
        for k in jax.random.split(key, cfg.local_iters):
            k1, k2 = jax.random.split(k)
            v1 = ssl.pi1(k1, images)
            v2 = ssl.pi2(k2, images)
            tree_c, key_tree_c = tree, key_tree

            def loss_fn(params):
                t = {"params": params, "state": tree_c["state"]}
                q, _, t2 = resnet_apply(t, v1, train=True)
                kv, _, _ = resnet_apply(key_tree_c, v2, train=False)
                kv = jax.lax.stop_gradient(kv)
                return info_nce_loss(q, kv, queue), (t2["state"], kv)

            (loss, (new_state, kvec)), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(tree_c["params"])
            new_params, opt_state = opt_update(tree_c["params"], grads,
                                               opt_state, lr)
            tree = {"params": new_params, "state": new_state}
            key_tree = {
                "params": ssl.momentum_update(key_tree_c["params"], new_params,
                                              cfg.moco_momentum),
                "state": new_state,
            }
            losses.append(loss)
        return tree, key_tree, kvec, jnp.stack(losses).mean()

    return local_train


# --------------------------------------------------------------------------
# trainer
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def _cached_local_steps(local_iters, momentum, weight_decay,
                        tau_alpha, tau_beta):
    f = make_local_train_step(FLConfig(
        local_iters=local_iters, momentum=momentum,
        weight_decay=weight_decay, tau_alpha=tau_alpha, tau_beta=tau_beta))
    return jax.jit(f), jax.jit(jax.vmap(f, in_axes=(0, 0, 0, None)))


def _jitted_local_steps(cfg: FLConfig):
    """Share jitted client steps across trainers.

    Keyed on exactly the fields the compiled step closes over — not the
    whole FLConfig — so seed/aggregator/round-count sweeps reuse one
    compilation. Bounded so long sweeps don't pin executables forever.
    """
    return _cached_local_steps(cfg.local_iters, cfg.momentum,
                               cfg.weight_decay, cfg.tau_alpha, cfg.tau_beta)


@functools.lru_cache(maxsize=16)
def _cached_moco_step(local_iters, momentum, weight_decay, moco_momentum):
    return jax.jit(make_moco_local_train_step(FLConfig(
        local_iters=local_iters, momentum=momentum,
        weight_decay=weight_decay, moco_momentum=moco_momentum)))


def _jitted_moco_step(cfg: FLConfig):
    return _cached_moco_step(cfg.local_iters, cfg.momentum,
                             cfg.weight_decay, cfg.moco_momentum)


class FederatedTrainer:
    """Simulates the RSU(s) + vehicle fleet of FLSimCo on host.

    Round structure is delegated to `topology` (default: the paper's
    `SingleRSU`); the trainer owns sampling, batching, and local SGD.
    """

    def __init__(self, cfg: FLConfig, global_tree, client_data: list,
                 mobility: Optional[MobilityModel] = None,
                 blur_images: bool = True,
                 topology: Optional[Topology] = None):
        if cfg.aggregator not in agg.AGGREGATORS and cfg.aggregator != "fedco":
            raise ValueError(
                f"unknown aggregator {cfg.aggregator!r}; valid: "
                f"{sorted(agg.AGGREGATORS) + ['fedco']}")
        self.cfg = cfg
        self.global_tree = global_tree
        self.client_data = client_data          # list of (images ndarray)
        self.mobility = mobility or MobilityModel()
        self.blur_images = blur_images
        self.lr_fn = cosine_schedule(cfg.lr, cfg.rounds)
        self.rng = np.random.RandomState(cfg.seed)
        self.key = jax.random.PRNGKey(cfg.seed)
        self._local, self._vlocal = _jitted_local_steps(cfg)
        self.history: list[dict] = []
        # FedCo state
        if cfg.aggregator == "fedco":
            self.key_tree = jax.tree.map(jnp.copy, global_tree)
            self.global_queue = jax.random.normal(
                jax.random.PRNGKey(cfg.seed + 1), (cfg.queue_len, cfg.feature_dim))
            self.global_queue /= jnp.linalg.norm(self.global_queue, axis=-1,
                                                 keepdims=True)
            self._moco_local = _jitted_moco_step(cfg)
        self.topology = topology if topology is not None else SingleRSU()
        self.topology.bind(self)

    # -- sampling ----------------------------------------------------------

    def _sample_round(self):
        n = self.cfg.vehicles_per_round
        ids = self.rng.choice(self.cfg.n_vehicles, size=n, replace=False)
        self.key, k = jax.random.split(self.key)
        velocities = self.mobility.sample(k, n)
        return ids, velocities

    def _client_batch(self, cid: int, velocity):
        data = self.client_data[cid]
        # fixed batch size across clients (vmapped cohorts need equal
        # shapes); small clients sample with replacement
        idx = self.rng.choice(len(data), size=self.cfg.batch_size,
                              replace=len(data) < self.cfg.batch_size)
        images = jnp.asarray(data[idx])
        if self.blur_images:
            images = apply_motion_blur(images, velocity,
                                       self.mobility.camera_const)
        return images

    # -- cohort execution + host aggregation (used by every topology) -------

    def _run_cohort(self, tree, ids, velocities, keys, lr,
                    parallel: bool = True, batches=None):
        """Run one cohort of clients from init model `tree`.

        Returns (client_trees, losses). `parallel=True` vmaps the cohort
        over a stacked tree; the sequential path is tested equivalent.
        `batches` lets a topology pre-draw batches in round order (the
        host RNG is a sequential stream, so draw order matters for
        cross-topology equivalence).
        """
        if batches is None:
            batches = jnp.stack([self._client_batch(c, v)
                                 for c, v in zip(ids, velocities)])
        if parallel:
            stacked = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (len(ids),) + x.shape), tree)
            trees, losses = self._vlocal(stacked, batches,
                                         jnp.stack(keys), lr)
            client_trees = [jax.tree.map(lambda x: x[i], trees)
                            for i in range(len(ids))]
            losses = list(np.asarray(losses))
        else:
            client_trees, losses = [], []
            for i, cid in enumerate(ids):
                t, l = self._local(tree, batches[i], keys[i], lr)
                client_trees.append(t)
                losses.append(float(l))
        return client_trees, losses

    def _host_aggregate(self, client_trees, velocities, blur):
        """Single-RSU Step 4: dispatch on the configured aggregator."""
        cfg = self.cfg
        if cfg.aggregator == "flsimco":
            return agg.aggregate_flsimco(client_trees, blur,
                                         cfg.normalize_weights)
        if cfg.aggregator == "discard":
            return agg.aggregate_discard(client_trees, velocities,
                                         cfg.blur_threshold)
        if cfg.aggregator == "softmax":            # beyond-paper variant
            return agg.aggregate_softmax(client_trees, blur)
        if cfg.aggregator == "inverse":            # beyond-paper variant
            return agg.aggregate_inverse(client_trees, blur)
        assert cfg.aggregator == "fedavg", cfg.aggregator  # ctor validates
        return agg.aggregate_fedavg(client_trees)

    # -- one round (Steps 2-4, structured by the topology) -------------------

    def round(self, r: int, parallel: bool = True) -> dict:
        rec = self.topology.run_round(self, r, parallel=parallel)
        self.history.append(rec)
        return rec

    def _round_fedco(self, r, ids, velocities, cks, lr) -> dict:
        trees, losses, kvec_list = [], [], []
        for i, cid in enumerate(ids):
            images = self._client_batch(cid, velocities[i])
            t, kt, kvecs, loss = self._moco_local(
                self.global_tree, self.key_tree, self.global_queue,
                images, cks[i], lr)
            trees.append(t)
            losses.append(float(loss))
            kvec_list.append(kvecs)
        # vehicles upload k-values; RSU merges them into the global queue
        self.global_queue = ssl.fedco_merge_queues(self.global_queue, kvec_list)
        self.global_tree = agg.aggregate_fedavg(trees)
        self.key_tree = jax.tree.map(jnp.copy, self.global_tree)
        # history is appended by round(), which every topology routes through
        return {"round": r, "loss": float(np.mean(losses)),
                "velocities": np.asarray(velocities).tolist(), "lr": float(lr)}

    def run(self, rounds: Optional[int] = None, log_every: int = 10,
            parallel: bool = True):
        for r in range(rounds if rounds is not None else self.cfg.rounds):
            rec = self.round(r, parallel=parallel)
            if log_every and r % log_every == 0:
                print(f"[round {r:4d}] loss={rec['loss']:.4f} lr={rec['lr']:.4f}")
        return self.history


def gradient_std(losses) -> float:
    """Paper Fig. 6 stability metric: std of the loss-curve gradient."""
    l = np.asarray(losses, np.float64)
    return float(np.std(np.diff(l)))
