"""Federated orchestration — FLSimCo Sec. 4 Steps 1-4 (legacy shim).

The simulation API is the pure one in `core/scenario.py`:

    sc = Scenario(topology=..., aggregator=..., client=..., ...)
    state = sc.init_state()                  # explicit FLState
    state, rec = run_round(state, sc)        # pure: state in -> state out

with `FLState` (core/state.py) carrying the RSU model, both RNG streams,
the round counter, per-topology vehicle state, and per-client-algorithm
state (FedCo key-tree + queue); client algorithms live in the
``CLIENT_UPDATES`` registry (core/clients.py) and aggregation schemes in
``AGGREGATORS`` (core/aggregation.py).

`FederatedTrainer` survives here as a thin back-compat shim that threads
an `FLState` through that API and accumulates history — no round logic
of its own. New code should use `Scenario`/`run_round` directly; the
shim exists so pre-redesign drivers keep working unchanged.

This module also re-exports the config/state types and the client local
train-step constructors so historical import paths
(`from repro.core.federation import FLConfig, make_local_train_step, ...`)
keep resolving.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.clients import (CLIENT_UPDATES, make_local_train_step,
                                make_moco_local_train_step)
from repro.core.mobility import MobilityModel
from repro.core.scenario import Scenario, run_round
from repro.core.state import FLConfig, FLState
from repro.core.topology import SingleRSU, Topology

__all__ = ["FLConfig", "FLState", "FederatedTrainer", "gradient_std",
           "CLIENT_UPDATES", "make_local_train_step",
           "make_moco_local_train_step"]


class FederatedTrainer:
    """Back-compat shim: an `FLState` threaded through `run_round`.

    Construction mirrors the old signature; every attribute the old
    trainer exposed is a read-only view into the scenario/state pair.
    """

    def __init__(self, cfg: FLConfig, global_tree, client_data: list,
                 mobility: Optional[MobilityModel] = None,
                 blur_images: bool = True,
                 topology: Optional[Topology] = None):
        self.scenario = Scenario(
            cfg,
            topology=topology if topology is not None else SingleRSU(),
            mobility=mobility, data=client_data, global_tree=global_tree,
            blur_images=blur_images)
        self.state: FLState = self.scenario.init_state()
        self.history: list[dict] = []

    # -- legacy attribute surface -------------------------------------------

    @property
    def cfg(self) -> FLConfig:
        return self.scenario.cfg

    @property
    def topology(self) -> Topology:
        return self.scenario.topology

    @property
    def mobility(self) -> MobilityModel:
        return self.scenario.mobility

    @property
    def global_tree(self):
        return self.state.global_tree

    @property
    def key(self):
        return self.state.key

    @property
    def key_tree(self):
        return self.state.client_state["key_tree"]

    @property
    def global_queue(self):
        return self.state.client_state["queue"]

    @property
    def lr_fn(self):
        return self.scenario.lr_fn

    # -- rounds --------------------------------------------------------------

    def round(self, r: Optional[int] = None, parallel: bool = True) -> dict:
        """Advance one round. `r` is accepted for signature compatibility
        but the round counter lives in the state (it must survive
        checkpoint/resume); a mismatching `r` is rejected."""
        if r is not None and r != self.state.round:
            raise ValueError(f"round index {r} does not match state round "
                             f"{self.state.round}; the counter lives in "
                             f"FLState now — call round() without it")
        self.state, rec = run_round(self.state, self.scenario,
                                    parallel=parallel)
        self.history.append(rec)
        return rec

    def run(self, rounds: Optional[int] = None, log_every: int = 10,
            parallel: bool = True):
        for r in range(rounds if rounds is not None else self.cfg.rounds):
            rec = self.round(parallel=parallel)
            if log_every and r % log_every == 0:
                print(f"[round {rec['round']:4d}] loss={rec['loss']:.4f} "
                      f"lr={rec['lr']:.4f}")
        return self.history


def gradient_std(losses) -> float:
    """Paper Fig. 6 stability metric: std of the loss-curve gradient."""
    l = np.asarray(losses, np.float64)
    return float(np.std(np.diff(l)))
