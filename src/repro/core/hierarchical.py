"""Hierarchical (two-level) blur-weighted aggregation — beyond-paper.

The paper has ONE RSU. A deployed vehicular network has many RSUs, each
aggregating its own vehicles, with a regional server (MEC / cloud) merging
the RSU models. Natural extension of Eq. 11:

  level 1 (RSU r):   theta_r = sum_{n in r} w_n theta_n,
                     w_n ∝ (Σ_r L − L_n)   over vehicles at RSU r
  level 2 (region):  theta   = sum_r W_r theta_r,
                     W_r ∝ (Σ L̄ − L̄_r)    over RSU mean blur levels,
                     optionally scaled by each RSU's vehicle count.

This maps 1:1 onto the production mesh: level 1 = weighted psum over
"data", level 2 = weighted psum over "pod" — the two-stage form of the
single collective in launch/steps.py. `hierarchical_equals_flat` shows
when the two coincide (count-scaled level-2 weights + equal blur).

Host-level forms here; the mesh-level two-stage reduce is
`two_stage_weighted_psum`. Equivalence covered by tests/test_hierarchical.py.

Sharded cohorts (DESIGN.md §Sharded cohorts): when the stacked cohort's
leading axis is partitioned over a ("pod", "data") mesh, the weighted
reductions here run under `shard_map`:

* `sharded_cohort_sum` / `sharded_aggregate` — the flat ``AGGREGATORS``
  sum with the cohort rows sharded. The default "gather" reduction
  all-gathers the rows (data movement only — bitwise identity) and
  applies the SAME `_weighted_stacked_sum` dispatch as the single-device
  path, so it is BIT-EXACT with `cohort_weighted_sum` for every scheme
  and backend; the "split" reduction all-to-alls row shards into
  parameter shards and reduces every row locally (row-summation order
  preserved — bit-exact with the tensordot/tree backend) while keeping
  per-device memory at O(m * P / devices).
* `sharded_hierarchical` — the two-level Eq. 11 with per-RSU blocks on
  the "pod" axis. reduction="exact" (default) composes per-level
  gathers with the host weight functions — bit-exact with
  `aggregate_hierarchical`; reduction="psum" routes through the
  (blocked) `two_stage_weighted_psum` collective — fewer bytes on the
  wire, documented-float-close (psum reassociates the row sum; the
  existing mesh tests pin atol=1e-5).

A psum of per-shard partial sums is NOT bit-exact versus the
single-device reduction (reassociation), which is why the bit-exact
forms are gathers/all-to-alls rather than "express everything as psum".
tests/multidevice/ enforces each contract under forced 8-device CPU.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core.aggregation import (SCHEME_WEIGHTS, _weighted_tree_sum,
                                    cohort_weighted_sum, flsimco_weights,
                                    weighted_psum_tree)
from repro.core.cohort import CohortBatch

COHORT_AXES = ("pod", "data")


def _as_cohort(group, blur) -> CohortBatch:
    """Normalize one RSU group to a `CohortBatch`: either it already is
    one (the round engine's stacked path — blur travels inside it), or a
    legacy (list of client trees, blur array) pair that gets stacked."""
    if isinstance(group, CohortBatch):
        return group
    blur = jnp.asarray(blur, jnp.float32)
    return CohortBatch.from_list(
        group, jnp.zeros((len(group),), jnp.float32), blur=blur)


def aggregate_hierarchical(groups: Sequence, blur_groups: Sequence = None,
                           count_scaled: bool = True):
    """groups[r] = the cohort at RSU r — a `CohortBatch` (stacked leaves +
    mask, blur attached) or a legacy list of client trees with
    blur_groups[r] = (N_r,) blur levels. Returns the region-level global
    model. Level-1 weights are computed on each cohort's valid slice, so
    padded (bucketed) cohorts aggregate bit-exactly like unpadded ones."""
    cohorts = [_as_cohort(g, None if blur_groups is None else b)
               for g, b in zip(groups, blur_groups or [None] * len(groups))]
    rsu_models = []
    rsu_blur = []
    rsu_count = []
    for cohort in cohorts:
        blur = cohort.valid_blur
        rsu_models.append(cohort_weighted_sum(cohort, flsimco_weights(blur)))
        rsu_blur.append(blur.mean())
        rsu_count.append(cohort.n)
    W = flsimco_weights(jnp.stack(rsu_blur))
    if count_scaled:
        W = W * _count_scale(tuple(rsu_count))
        W = W / jnp.sum(W)
    return _weighted_tree_sum(rsu_models, W)


@functools.lru_cache(maxsize=128)
def _count_scale(counts) -> jnp.ndarray:
    """Device-resident per-RSU vehicle counts, cached by value: RSU
    geometry repeats every round, so the count vector must not be
    re-uploaded per aggregation call (lint rule retrace-fresh-array)."""
    return jnp.asarray(counts, jnp.float32)


def two_stage_weighted_psum(tree, blur_level, *, rsu_axis="data",
                            region_axis="pod", count_scaled=True,
                            accum_dtype=None):
    """Mesh-level hierarchical Eq. 11: weighted psum over `rsu_axis`, then
    over `region_axis`. Call inside shard_map with both axes bound.

    blur_level: this device's L — a SCALAR when every device holds one
    vehicle (the original one-device-per-vehicle form), or a (b,) BLOCK
    when the cohort axis is blocked over the mesh (b vehicles per device;
    `tree` then carries a leading (b, ...) axis). The blocked form sums
    each device's weighted rows locally and psums the partials — the
    collective moves one model per device instead of b, at the cost of
    reassociating the row sum (documented-float-close versus the host
    path; the bit-exact alternative is `sharded_hierarchical`'s gather
    form). With count-scaled level-2 weights and equal per-RSU cohort
    counts this equals the flat single-psum form.

    accum_dtype: None (default) keeps the existing op sequence — f32
    weighted sums, cast back per level — bit-compatible with the pinned
    mesh tests. A wider dtype (e.g. jnp.float64 under enable_x64) makes
    BOTH weighted reductions accumulate in that dtype, casting back to
    each leaf's dtype only after level 2 — the psum reassociation error
    then shrinks from ~1e-6 to the f32 rounding floor
    (tests/test_hierarchical.py pins the tightened tolerance).
    """
    # analysis: allow=retrace-fresh-array -- traced under shard_map;
    # these constants fold at compile time, nothing runs per call
    L = jnp.asarray(blur_level, jnp.float32)
    blocked = L.ndim > 0
    # level 1: vehicles within the RSU
    tot1 = jax.lax.psum(L.sum() if blocked else L, rsu_axis)
    # analysis: allow=retrace-fresh-array -- traced constants (see above)
    n1 = jax.lax.psum(jnp.asarray(L.size, jnp.float32) if blocked
                      else jnp.ones(()), rsu_axis)
    w1 = (tot1 - L) / jnp.maximum(tot1, 1e-12)
    s1 = jax.lax.psum(w1.sum() if blocked else w1, rsu_axis)
    w1 = jnp.where(s1 > 1e-12, w1 / jnp.maximum(s1, 1e-12), 1.0 / n1)
    ad = None if accum_dtype is None else jnp.dtype(accum_dtype)
    if blocked:
        def red(x):
            if ad is not None:
                return jax.lax.psum(
                    jnp.tensordot(w1.astype(ad), x.astype(ad), axes=1),
                    rsu_axis)
            y = jnp.tensordot(w1, x.astype(jnp.float32), axes=1)
            return jax.lax.psum(y, rsu_axis).astype(x.dtype)
        rsu_model = jax.tree.map(red, tree)
    elif ad is not None:
        rsu_model = jax.tree.map(
            lambda x: jax.lax.psum(x.astype(ad) * w1.astype(ad), rsu_axis),
            tree)
    else:
        rsu_model = weighted_psum_tree(tree, w1, rsu_axis)
    # level 2: RSUs within the region. psum over `region_axis` alone sums
    # one representative per pod (the rsu-level quantities are replicated
    # across rsu_axis after the level-1 psum) — no double counting.
    Lbar = tot1 / n1
    tot2 = jax.lax.psum(Lbar, region_axis)
    # analysis: allow=retrace-fresh-array -- traced constant (see above)
    n2 = jax.lax.psum(jnp.ones(()), region_axis)
    w2 = (tot2 - Lbar) / jnp.maximum(tot2, 1e-12)
    if count_scaled:
        w2 = w2 * n1
    s2 = jax.lax.psum(w2, region_axis)
    w2 = jnp.where(s2 > 1e-12, w2 / jnp.maximum(s2, 1e-12), 1.0 / n2)
    if ad is not None:
        # rsu_model is still in accum_dtype; cast back only after the
        # final reduction (target dtypes come from the input leaves)
        out = jax.tree.map(
            lambda x: jax.lax.psum(x * w2.astype(ad), region_axis),
            rsu_model)
        return jax.tree.map(lambda o, x: o.astype(x.dtype), out, tree)
    return weighted_psum_tree(rsu_model, w2, region_axis)


# --------------------------------------------------------------------------
# sharded cohorts: the masked weighted sums under shard_map
# --------------------------------------------------------------------------

def _mesh_extent(mesh) -> int:
    ext = 1
    for a in COHORT_AXES:
        if a in mesh.axis_names:
            ext *= mesh.shape[a]
    return ext


@functools.lru_cache(maxsize=64)
def _flat_gather_fn(mesh, backend: str):
    """shard_map'd masked cohort sum, "gather" reduction: all-gather the
    row shards (pure data movement) and run the SAME
    `_weighted_stacked_sum` dispatch as the single-device path on the
    reassembled cohort — bit-exact by construction, on any backend."""
    from repro.compat import shard_map

    def body(blk_trees, w, mask):
        full = jax.tree.map(
            lambda x: jax.lax.all_gather(x, COHORT_AXES, tiled=True),
            blk_trees)
        with agg.wagg_backend(backend):
            return agg._weighted_stacked_sum(full, w, mask)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P(COHORT_AXES), P(), P()),
                             out_specs=P(), check=False))


@functools.lru_cache(maxsize=64)
def _flat_split_fn(mesh):
    """shard_map'd masked cohort sum, "split" reduction: all-to-all the
    (rows/D, P) row shards into (rows, P/D) parameter shards, then reduce
    ALL rows locally over the parameter slice. Per-output-element the row
    summation order is identical to the single-device tensordot, so the
    result is bit-exact with the tree backend while per-device memory
    stays at O(rows * P / D)."""
    from repro.compat import shard_map

    def body(flat_blk, w):
        cols = jax.lax.all_to_all(flat_blk, COHORT_AXES, split_axis=1,
                                  concat_axis=0, tiled=True)
        return jnp.tensordot(w, cols, axes=1)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P(COHORT_AXES), P()),
                             out_specs=P(COHORT_AXES), check=False))


def sharded_cohort_sum(cohort: CohortBatch, w_valid, mesh, *,
                       reduction: str = "gather"):
    """`cohort_weighted_sum` with the cohort rows sharded over `mesh`.

    (n,) weights over the valid rows, zero-padded to the (possibly
    re-padded) cohort size; rows shard P(("pod", "data")). Bit-exact with
    the single-device `cohort_weighted_sum` — "gather" on every backend,
    "split" versus the tensordot (tree) backend (test-enforced in
    tests/multidevice/). Cohorts whose padded size does not divide the
    mesh extent are re-padded first (`CohortBatch.pad_to` — replicated
    finite rows, zero weights, exact +0.0 terms), so a cohort SMALLER
    than the mesh still works: whole shards of padding reduce to
    nothing.
    """
    if reduction not in ("gather", "split"):
        raise ValueError(f"reduction {reduction!r} not in "
                         f"('gather', 'split')")
    ext = _mesh_extent(mesh)
    m = -(-cohort.size // ext) * ext
    cohort = cohort.pad_to(m)
    w = cohort.padded_weights(w_valid)
    if reduction == "gather":
        fn = _flat_gather_fn(mesh, agg._resolve_wagg_backend())
        return fn(cohort.trees, w, cohort.mask)
    # split: ravel the stacked leaves to one (m, P) f32 matrix (the same
    # layout wagg_stacked kernels consume), pad P to a multiple of the
    # mesh extent, reduce, unravel
    w = w * cohort.mask               # mask is float32 by construction
    leaves = jax.tree.leaves(cohort.trees)
    flat = jnp.concatenate(
        [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)
    P_total = flat.shape[1]
    pad = (-P_total) % ext
    if pad:
        # analysis: allow=retrace-fresh-array -- device-side zero pad;
        # its width follows the cohort, there is no constant to hoist
        flat = jnp.concatenate(
            [flat, jnp.zeros((m, pad), jnp.float32)], axis=1)
    out = _flat_split_fn(mesh)(flat, w)[:P_total]
    from repro.kernels.ops import _unravel_like
    return _unravel_like(out, jax.tree.map(lambda x: x[0], cohort.trees))


def sharded_aggregate(cohort: CohortBatch, cfg, mesh, *,
                      scheme: str = None, reduction: str = "gather"):
    """``AGGREGATORS[scheme]`` with the reduction sharded over `mesh`.

    The weights come from the SAME ``SCHEME_WEIGHTS`` entry the
    single-device dispatch uses, computed on the replicated valid slice
    (`cohort.valid_blur` is (n,) — tiny), so the sharded result is
    bit-exact with `AGGREGATORS[scheme](cohort, cfg)` for all five
    schemes (acceptance-tested under forced 8-device CPU).
    """
    scheme = cfg.aggregator if scheme is None else scheme
    w = SCHEME_WEIGHTS[scheme](cohort, cfg)
    return sharded_cohort_sum(cohort, w, mesh, reduction=reduction)


@functools.lru_cache(maxsize=64)
def _hier_exact_fn(mesh, backend: str):
    """shard_map'd two-level Eq. 11, gather form: level 1 gathers each
    RSU's rows over "data" and reduces with the host dispatch; level 2
    gathers the per-RSU models over "pod" and reduces with the host
    dispatch. Both weight vectors arrive replicated (computed outside by
    the host weight functions), so every arithmetic op matches
    `aggregate_hierarchical` bit for bit."""
    from repro.compat import shard_map

    def body(blk_trees, w1_blk, W2):
        blk = jax.tree.map(
            lambda x: jax.lax.all_gather(x, "data", tiled=True), blk_trees)
        w1 = jax.lax.all_gather(w1_blk, "data", tiled=True)
        with agg.wagg_backend(backend):
            rsu_model = agg._weighted_stacked_sum(blk, w1)
            stack = jax.tree.map(
                lambda x: jax.lax.all_gather(x, "pod"), rsu_model)
            return agg._weighted_stacked_sum(stack, W2)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P(COHORT_AXES), P(COHORT_AXES), P()),
                             out_specs=P(), check=False))


@functools.lru_cache(maxsize=64)
def _hier_psum_fn(mesh, count_scaled: bool, accum_name: str = None):
    from repro.compat import shard_map
    accum_dtype = None if accum_name is None else jnp.dtype(accum_name)

    def body(blk_trees, blur_blk):
        return two_stage_weighted_psum(blk_trees, blur_blk,
                                       count_scaled=count_scaled,
                                       accum_dtype=accum_dtype)

    return jax.jit(shard_map(body, mesh=mesh,
                             in_specs=(P(COHORT_AXES), P(COHORT_AXES)),
                             out_specs=P(), check=False))


def sharded_hierarchical(stacked_trees, blur, mesh, n_rsus: int, *,
                         count_scaled: bool = True,
                         reduction: str = "exact",
                         accum_dtype=None):
    """Two-level Eq. 11 over an RSU-MAJOR stacked cohort sharded on
    `mesh` (pod=n_rsus, data=d with d | per-RSU size).

    stacked_trees: every leaf (n_rsus * s, ...), RSU r's vehicles in rows
    [r*s, (r+1)*s); blur: (n_rsus * s,) matching. reduction="exact"
    (default) computes both weight levels with the host functions on the
    replicated blur and reduces via gathers — bit-exact with
    `aggregate_hierarchical` on the same cohorts; reduction="psum" is the
    blocked `two_stage_weighted_psum` collective — one model per device
    on the wire, float-close (atol~1e-5). accum_dtype widens the psum
    reduction's accumulator (see `two_stage_weighted_psum`); it has no
    effect on the already-bit-exact "exact" reduction.
    """
    if reduction not in ("exact", "psum"):
        raise ValueError(f"reduction {reduction!r} not in ('exact', 'psum')")
    R = n_rsus
    m = int(jnp.shape(blur)[0])
    if m % R:
        raise ValueError(f"rsu-major cohort of {m} rows not divisible by "
                         f"n_rsus={R}")
    s = m // R
    if reduction == "psum":
        accum_name = None if accum_dtype is None \
            else jnp.dtype(accum_dtype).name
        # analysis: allow=retrace-fresh-array -- f32 normalization at
        # the aggregation boundary (no-op when blur is already jnp f32)
        return _hier_psum_fn(mesh, count_scaled, accum_name)(
            stacked_trees, jnp.asarray(blur, jnp.float32))
    # weights exactly as aggregate_hierarchical computes them: per-RSU
    # level-1 weights on each (s,) blur block, level-2 on the stacked
    # block means (count-scaled) — all on replicated (tiny) arrays
    # analysis: allow=retrace-fresh-array -- f32 normalization at the
    # aggregation boundary (no-op when blur is already jnp f32)
    blur = jnp.asarray(blur, jnp.float32)
    blocks = [blur[r * s:(r + 1) * s] for r in range(R)]
    w1 = jnp.concatenate([flsimco_weights(b) for b in blocks])
    W2 = flsimco_weights(jnp.stack([b.mean() for b in blocks]))
    if count_scaled:
        # cached: same values as jnp.full((R,), s) but not rebuilt per call
        W2 = W2 * _count_scale((s,) * R)
        W2 = W2 / jnp.sum(W2)
    fn = _hier_exact_fn(mesh, agg._resolve_wagg_backend())
    return fn(stacked_trees, w1, W2)


def reset_sharded_caches() -> None:
    """Drop every cached shard_map'd aggregation callable (test/benchmark
    isolation — mirrors `engine.reset_engine_caches`)."""
    _flat_gather_fn.cache_clear()
    _flat_split_fn.cache_clear()
    _hier_exact_fn.cache_clear()
    _hier_psum_fn.cache_clear()
    _count_scale.cache_clear()
