"""Hierarchical (two-level) blur-weighted aggregation — beyond-paper.

The paper has ONE RSU. A deployed vehicular network has many RSUs, each
aggregating its own vehicles, with a regional server (MEC / cloud) merging
the RSU models. Natural extension of Eq. 11:

  level 1 (RSU r):   theta_r = sum_{n in r} w_n theta_n,
                     w_n ∝ (Σ_r L − L_n)   over vehicles at RSU r
  level 2 (region):  theta   = sum_r W_r theta_r,
                     W_r ∝ (Σ L̄ − L̄_r)    over RSU mean blur levels,
                     optionally scaled by each RSU's vehicle count.

This maps 1:1 onto the production mesh: level 1 = weighted psum over
"data", level 2 = weighted psum over "pod" — the two-stage form of the
single collective in launch/steps.py. `hierarchical_equals_flat` shows
when the two coincide (count-scaled level-2 weights + equal blur).

Host-level forms here; the mesh-level two-stage reduce is
`two_stage_weighted_psum`. Equivalence covered by tests/test_hierarchical.py.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.aggregation import (_weighted_tree_sum, cohort_weighted_sum,
                                    flsimco_weights, weighted_psum_tree)
from repro.core.cohort import CohortBatch


def _as_cohort(group, blur) -> CohortBatch:
    """Normalize one RSU group to a `CohortBatch`: either it already is
    one (the round engine's stacked path — blur travels inside it), or a
    legacy (list of client trees, blur array) pair that gets stacked."""
    if isinstance(group, CohortBatch):
        return group
    blur = jnp.asarray(blur, jnp.float32)
    return CohortBatch.from_list(
        group, jnp.zeros((len(group),), jnp.float32), blur=blur)


def aggregate_hierarchical(groups: Sequence, blur_groups: Sequence = None,
                           count_scaled: bool = True):
    """groups[r] = the cohort at RSU r — a `CohortBatch` (stacked leaves +
    mask, blur attached) or a legacy list of client trees with
    blur_groups[r] = (N_r,) blur levels. Returns the region-level global
    model. Level-1 weights are computed on each cohort's valid slice, so
    padded (bucketed) cohorts aggregate bit-exactly like unpadded ones."""
    cohorts = [_as_cohort(g, None if blur_groups is None else b)
               for g, b in zip(groups, blur_groups or [None] * len(groups))]
    rsu_models = []
    rsu_blur = []
    rsu_count = []
    for cohort in cohorts:
        blur = cohort.valid_blur
        rsu_models.append(cohort_weighted_sum(cohort, flsimco_weights(blur)))
        rsu_blur.append(blur.mean())
        rsu_count.append(cohort.n)
    W = flsimco_weights(jnp.stack(rsu_blur))
    if count_scaled:
        c = jnp.asarray(rsu_count, jnp.float32)
        W = W * c
        W = W / jnp.sum(W)
    return _weighted_tree_sum(rsu_models, W)


def two_stage_weighted_psum(tree, blur_level, *, rsu_axis="data",
                            region_axis="pod", count_scaled=True):
    """Mesh-level hierarchical Eq. 11: weighted psum over `rsu_axis`, then
    over `region_axis`. Call inside shard_map with both axes bound.

    blur_level: this cohort's scalar L. With count-scaled level-2 weights
    and equal per-RSU cohort counts this equals the flat single-psum form.
    """
    L = jnp.asarray(blur_level, jnp.float32)
    # level 1: vehicles within the RSU
    tot1 = jax.lax.psum(L, rsu_axis)
    n1 = jax.lax.psum(jnp.ones(()), rsu_axis)
    w1 = (tot1 - L) / jnp.maximum(tot1, 1e-12)
    s1 = jax.lax.psum(w1, rsu_axis)
    w1 = jnp.where(s1 > 1e-12, w1 / jnp.maximum(s1, 1e-12), 1.0 / n1)
    rsu_model = weighted_psum_tree(tree, w1, rsu_axis)
    # level 2: RSUs within the region. psum over `region_axis` alone sums
    # one representative per pod (the rsu-level quantities are replicated
    # across rsu_axis after the level-1 psum) — no double counting.
    Lbar = tot1 / n1
    tot2 = jax.lax.psum(Lbar, region_axis)
    n2 = jax.lax.psum(jnp.ones(()), region_axis)
    w2 = (tot2 - Lbar) / jnp.maximum(tot2, 1e-12)
    if count_scaled:
        w2 = w2 * n1
    s2 = jax.lax.psum(w2, region_axis)
    w2 = jnp.where(s2 > 1e-12, w2 / jnp.maximum(s2, 1e-12), 1.0 / n2)
    return weighted_psum_tree(rsu_model, w2, region_axis)
