"""Vehicle mobility + motion-blur model — FLSimCo Eq. (1)-(2).

Velocities are IID truncated Gaussians on [v_min, v_max] (Eq. 1); the
blur level of a vehicle's locally captured images is linear in velocity,
L_n = (H*s/Q) * v_n (Eq. 2), where H*s/Q is a camera constant.

Table 1 gives v_min = 16.67 m/s, v_max = 41.67 m/s, camera constant 0.58.
The paper does not state (mu, sigma); we default to the interval midpoint
and sigma = 5 m/s (recorded assumption). The paper's Fig. 6 threshold
"blurred above 100 km/h" = 27.78 m/s is exposed for baseline2.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

KMH_100 = 100.0 / 3.6  # 27.78 m/s — paper's velocity cutoff for baseline2
CAMERA_CONST = 0.58    # H*s/Q, Table 1 — the Eq.-2 blur-per-velocity slope
# The 100 km/h cutoff in BLUR units (Eq. 2 under the Table-1 camera
# constant): baseline2 ("discard") drops clients whose blur level
# exceeds this — the blur a camera records at exactly 100 km/h.
# FLConfig.blur_threshold defaults to it, and launch/steps.py uses it
# for the mesh-level discard. A scenario with a non-default
# MobilityModel.camera_const must scale its blur_threshold accordingly
# (the threshold is a blur level, not a velocity).
BLUR_KMH_100 = CAMERA_CONST * KMH_100  # ~16.11


@dataclass(frozen=True)
class MobilityModel:
    v_min: float = 16.67
    v_max: float = 41.67
    mu: float = (16.67 + 41.67) / 2
    sigma: float = 5.0
    camera_const: float = CAMERA_CONST   # H*s/Q  (Table 1: 0.58)

    def pdf(self, v):
        """Truncated Gaussian pdf, Eq. (1)."""
        v = jnp.asarray(v, jnp.float32)
        z = (v - self.mu) / self.sigma
        base = jnp.exp(-0.5 * z * z) / (self.sigma * math.sqrt(2 * math.pi))
        lo = math.erf((self.v_min - self.mu) / (self.sigma * math.sqrt(2)))
        hi = math.erf((self.v_max - self.mu) / (self.sigma * math.sqrt(2)))
        norm = 0.5 * (hi - lo)
        inside = (v >= self.v_min) & (v <= self.v_max)
        return jnp.where(inside, base / norm, 0.0)

    def sample(self, key, n: int):
        """n velocities via rejection-free inverse-ish sampling: sample the
        untruncated Gaussian and resample out-of-range values uniformly from
        a fine inverse-cdf grid (exact in distribution up to grid)."""
        # inverse-CDF on a grid: robust, jit-friendly, exactly truncated
        grid = jnp.linspace(self.v_min, self.v_max, 4097)
        pdf = self.pdf(grid)
        cdf = jnp.cumsum(pdf)
        cdf = cdf / cdf[-1]
        u = jax.random.uniform(key, (n,))
        idx = jnp.searchsorted(cdf, u)
        return grid[jnp.clip(idx, 0, grid.shape[0] - 1)]

    def blur_level(self, v):
        """Eq. (2): L = (H*s/Q) * v."""
        return self.camera_const * jnp.asarray(v, jnp.float32)

    def is_blurred(self, v, threshold=KMH_100):
        return jnp.asarray(v) > threshold

    # -- positions (multi-RSU handover, beyond-paper) ----------------------
    # The paper needs only velocities (one RSU covers everyone). The
    # handover topology (core/topology.py) additionally tracks where each
    # vehicle *is*: a ring road of length `road_length` partitioned into
    # equal RSU coverage ranges, positions advancing by v*dt per round.

    def init_positions(self, key, n: int, road_length: float):
        """Uniform initial positions on the ring road [0, road_length)."""
        return jax.random.uniform(key, (n,), minval=0.0, maxval=road_length)

    def advance_positions(self, positions, velocities, dt: float,
                          road_length: float):
        """positions + v*dt, wrapped (vehicles circulate the ring road)."""
        p = jnp.asarray(positions, jnp.float32)
        v = jnp.asarray(velocities, jnp.float32)
        return jnp.mod(p + v * dt, road_length)


def motion_blur_kernel(v, camera_const: float = CAMERA_CONST,
                       max_len: int = 9):
    """Horizontal linear motion-blur PSF whose length grows with velocity.

    Discretized Eq. (2): blur extent (pixels) = clip(round(L), 1, max_len).
    Returns (max_len,) kernel (zero-padded, normalized) — usable under vmap
    over per-vehicle velocities.
    """
    L = camera_const * jnp.asarray(v, jnp.float32)
    extent = jnp.clip(L / 2.0, 1.0, float(max_len))
    idx = jnp.arange(max_len, dtype=jnp.float32)
    center = (max_len - 1) / 2.0
    w = jnp.where(jnp.abs(idx - center) <= (extent - 1.0) / 2.0 + 1e-6, 1.0, 0.0)
    w = jnp.maximum(w, jnp.where(idx == center, 1.0, 0.0))   # at least identity
    return w / w.sum()


def apply_motion_blur(images, v, camera_const: float = CAMERA_CONST,
                      max_len: int = 9):
    """Blur (B,H,W,C) images with the velocity-dependent horizontal PSF."""
    k = motion_blur_kernel(v, camera_const, max_len)          # (max_len,)
    pad = max_len // 2
    x = jnp.pad(images, ((0, 0), (0, 0), (pad, pad), (0, 0)), mode="edge")
    # depthwise 1-D conv along W
    def shift_sum(i):
        return x[:, :, i:i + images.shape[2], :] * k[i]
    out = sum(shift_sum(i) for i in range(max_len))
    return out
