"""Declarative experiment builder + the pure round API.

A `Scenario` names every static choice of an FLSimCo experiment — client
algorithm, aggregation scheme, topology, mobility model, data partition,
backbone — and the pure functions thread an explicit `FLState` through
it:

    sc = Scenario(topology="handover", aggregator="flsimco",
                  partitioner="dirichlet", alpha=0.1,
                  n_vehicles=8, vehicles_per_round=4, batch_size=32,
                  rounds=6, topology_kwargs={"n_rsus": 3})
    state = sc.init_state()
    state, rec = run_round(state, sc)            # one pure round
    state, history = run(sc, state, rounds=5)    # or many

`run_round` never mutates its inputs: checkpoint `state.to_tree()` at any
round, restore later (`FLState.from_tree`), and the continuation is
bit-identical to a run that never paused (tests/test_state.py). The
legacy `FederatedTrainer` (core/federation.py) is a thin shim over
exactly this API.

Scenario construction is declarative and lazy: dataset/partition and the
backbone init are built on first use, so a grid of Scenarios is cheap to
enumerate (benchmarks/) and a Scenario with explicit `data=`/
`global_tree=` skips the builders entirely (the trainer shim path).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.clients import CLIENT_UPDATES
from repro.core.mobility import MobilityModel
from repro.core.state import (FLConfig, FLState, pack_host_rng,
                              resolve_fedco_alias)
from repro.core.topology import TOPOLOGIES, Topology
from repro.optim.optimizers import cosine_schedule

PARTITIONERS = ("iid", "dirichlet")


class Scenario:
    """Static description of one federated experiment.

    Everything that does NOT change round to round lives here; everything
    that does lives in `FLState`. Accepts either a ready `FLConfig` (plus
    optional field overrides) or bare FLConfig kwargs:

        Scenario(cfg, topology="multi", aggregator="softmax")
        Scenario(topology="single", client="fedco", n_vehicles=8, rounds=4)

    topology         name in ``TOPOLOGIES`` (+ `topology_kwargs`) or an
                     instance
    aggregator       name in ``AGGREGATORS`` (overrides cfg.aggregator)
    client           name in ``CLIENT_UPDATES`` (overrides cfg.client)
    mobility         `MobilityModel` (velocity distribution + camera)
    partitioner      "iid" | "dirichlet" — how the synthetic dataset is
                     split across vehicles (alpha/min_per_client/
                     n_per_class/data_seed tune it); ignored when `data=`
                     is passed explicitly
    data             per-vehicle image arrays (skips the dataset builder)
    global_tree      round-0 model (default: init `arch` from cfg.seed)
    """

    def __init__(self, cfg: Optional[FLConfig] = None, *,
                 topology: Union[str, Topology] = "single",
                 aggregator: Optional[str] = None,
                 client: Optional[str] = None,
                 mobility: Optional[MobilityModel] = None,
                 partitioner: str = "iid",
                 alpha: float = 0.1,
                 n_per_class: int = 100,
                 min_per_client: int = 0,
                 data_seed: int = 0,
                 arch: str = "resnet18-cifar",
                 data: Optional[Sequence] = None,
                 global_tree: Any = None,
                 blur_images: bool = True,
                 topology_kwargs: Optional[dict] = None,
                 **cfg_kwargs):
        if cfg is None:
            cfg = FLConfig(**cfg_kwargs)
        elif cfg_kwargs:
            cfg = dataclasses.replace(cfg, **cfg_kwargs)
        # resolve the legacy "fedco" alias BEFORE dataclasses.replace: the
        # base cfg's client field is already normalized to a concrete name,
        # which FLConfig could not tell apart from an explicit request
        aggregator, client = resolve_fedco_alias(aggregator, client)
        overrides = {}
        if aggregator is not None:
            overrides["aggregator"] = aggregator
        if client is not None:
            overrides["client"] = client
        if overrides:
            cfg = dataclasses.replace(cfg, **overrides)
        self.cfg = cfg
        if isinstance(topology, str):
            if topology not in TOPOLOGIES:
                raise ValueError(f"unknown topology {topology!r}; valid: "
                                 f"{sorted(TOPOLOGIES)}")
            topology = TOPOLOGIES[topology](**(topology_kwargs or {}))
        elif topology_kwargs:
            raise ValueError("topology_kwargs only applies when `topology` "
                             "is a registry name")
        self.topology = topology
        self.mobility = mobility if mobility is not None else MobilityModel()
        self.blur_images = blur_images
        if partitioner not in PARTITIONERS:
            raise ValueError(f"unknown partitioner {partitioner!r}; valid: "
                             f"{sorted(PARTITIONERS)}")
        self.partitioner = partitioner
        self.alpha = alpha
        self.n_per_class = n_per_class
        self.min_per_client = min_per_client
        self.data_seed = data_seed
        self.arch = arch
        self._data = list(data) if data is not None else None
        self._dataset = None
        self._global_tree = global_tree
        self._lr_fn = None
        self.topology.validate(self.cfg)

    # -- lazy builders -------------------------------------------------------

    @property
    def data(self) -> list:
        """Per-vehicle image arrays (built on first access)."""
        if self._data is None:
            x, y = self.dataset
            from repro.data.synthetic import (partition_dirichlet,
                                              partition_iid)
            if self.partitioner == "iid":
                parts = partition_iid(y, self.cfg.n_vehicles,
                                      seed=self.data_seed)
            else:
                parts = partition_dirichlet(
                    y, self.cfg.n_vehicles, alpha=self.alpha,
                    min_per_client=self.min_per_client, seed=self.data_seed)
            self._data = [x[p] for p in parts]
        return self._data

    @property
    def dataset(self):
        """The full (images, labels) pool — probes evaluate against this."""
        if self._dataset is None:
            from repro.data.synthetic import make_dataset
            self._dataset = make_dataset(n_per_class=self.n_per_class,
                                         seed=self.data_seed)
        return self._dataset

    def init_tree(self):
        """Round-0 model (built from `arch` + cfg.seed unless provided)."""
        if self._global_tree is None:
            from repro.configs.base import get_config
            from repro.models.resnet import init_resnet
            self._global_tree = init_resnet(
                get_config(self.arch), jax.random.PRNGKey(self.cfg.seed))
        return self._global_tree

    @property
    def lr_fn(self):
        if self._lr_fn is None:
            self._lr_fn = cosine_schedule(self.cfg.lr, self.cfg.rounds)
        return self._lr_fn

    # -- state ---------------------------------------------------------------

    def init_state(self) -> FLState:
        """The round-0 `FLState`: model, both RNG streams, per-client and
        per-topology state. Deterministic in cfg.seed."""
        cfg = self.cfg
        tree = self.init_tree()
        key = jax.random.PRNGKey(cfg.seed)
        rng = np.random.RandomState(cfg.seed)
        client_state = CLIENT_UPDATES[cfg.client].init_state(cfg, tree)
        topo, key = self.topology.init_state(cfg, self.mobility, tree, key)
        from repro.comms.codecs import comms_init_state
        comms = comms_init_state(cfg, tree)
        return FLState(global_tree=tree, key=key,
                       host_rng=pack_host_rng(rng), round=0,
                       topo=topo, client_state=client_state, comms=comms)


# --------------------------------------------------------------------------
# pure entry points
# --------------------------------------------------------------------------

def run_round(state: FLState, scenario: Scenario, parallel: bool = True):
    """One federated round: (state, scenario) -> (state, record). Pure —
    the input state is never mutated, and the same state yields the same
    output bit for bit."""
    return scenario.topology.run_round(state, scenario, parallel=parallel)


def run(scenario: Scenario, state: Optional[FLState] = None,
        rounds: Optional[int] = None, parallel: bool = True,
        log_every: int = 0, publish=None):
    """Run `rounds` rounds (default cfg.rounds) from `state` (default the
    scenario's round-0 state). Returns (final state, list of records).

    This is the eager loop: one `run_round` dispatch per round, one
    history fetch per round. `run_campaign` runs the same campaign
    through the compiled engine (core/engine.py) with an identical
    schedule and once-per-chunk history fetches. ``publish`` is the
    serving hook — called as ``publish(round, tree)`` after every round
    (the eager analogue of `engine.run_campaign`'s once-per-chunk
    publish; see repro.serve)."""
    if state is None:
        state = scenario.init_state()
    history = []
    for _ in range(rounds if rounds is not None else scenario.cfg.rounds):
        state, rec = run_round(state, scenario, parallel=parallel)
        history.append(rec)
        if publish is not None:
            publish(state.round, state.global_tree)
        if log_every and rec["round"] % log_every == 0:
            print(f"[round {rec['round']:4d}] loss={rec['loss']:.4f} "
                  f"lr={rec['lr']:.4f}")
    return state, history


def run_campaign(scenario: Scenario, state: Optional[FLState] = None,
                 rounds: Optional[int] = None, **kwargs):
    """Compiled form of `run`: pre-draws the whole schedule from the
    same RNG streams, then executes one jitted round body per round
    ("jit" mode) or `lax.scan` chunks ("scan" mode) — see
    core/engine.py for modes, checkpointing and the bit-exactness
    contract. Signature sugar over `engine.run_campaign`."""
    from repro.core.engine import run_campaign as _run_campaign
    return _run_campaign(scenario, state, rounds, **kwargs)
