"""Self-supervised learning machinery: augmentations + baselines.

* pi1 / pi2 — FLSimCo Sec. 4 Step 2 image augmentations, implemented as
  pure-JAX ops (no PIL/torchvision in this container):
    pi1: horizontal flip (p=.5) -> grayscale (p=.2)
    pi2: color jitter (brightness/contrast/saturation/hue, range .4, p=.8)
         -> grayscale (p=.4)
* token views — the framework's extension of the DT objective to token
  architectures (DESIGN.md §2): two stochastic token-dropout/masking views.
* MoCo machinery (momentum encoder EMA + negative queue) and the FedCo
  global-queue protocol — the paper's comparison baselines.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

GRAY_W = jnp.array([0.299, 0.587, 0.114], jnp.float32)


# --------------------------------------------------------------------------
# image augmentations (pi1 / pi2)
# --------------------------------------------------------------------------

def _grayscale(x):
    g = jnp.tensordot(x, GRAY_W, axes=[[-1], [0]])[..., None]
    return jnp.broadcast_to(g, x.shape)


def _maybe(key, p, fn, x):
    do = jax.random.bernoulli(key, p, (x.shape[0],))
    return jnp.where(do[:, None, None, None], fn(x), x)


def _jitter_factors(key, b, rng=0.4):
    ks = jax.random.split(key, 4)
    f = [jax.random.uniform(k, (b, 1, 1, 1), minval=1 - rng, maxval=1 + rng)
         for k in ks[:3]]
    hue = jax.random.uniform(ks[3], (b, 1, 1), minval=-rng, maxval=rng)
    return f[0], f[1], f[2], hue


def _color_jitter(key, x, rng=0.4):
    br, ct, sat, hue = _jitter_factors(key, x.shape[0], rng)
    x = x * br                                               # brightness
    mean = x.mean(axis=(1, 2, 3), keepdims=True)
    x = (x - mean) * ct + mean                               # contrast
    g = _grayscale(x)
    x = g + (x - g) * sat[..., None] if sat.ndim == 3 else g + (x - g) * sat
    # hue: rotate chroma around the gray axis (small-angle YIQ rotation)
    theta = hue[..., None] * jnp.pi
    cos, sin = jnp.cos(theta), jnp.sin(theta)
    y = _grayscale(x)
    r, g_, b = x[..., 0:1], x[..., 1:2], x[..., 2:3]
    i = 0.596 * r - 0.274 * g_ - 0.322 * b
    q = 0.211 * r - 0.523 * g_ + 0.312 * b
    i2 = cos * i - sin * q
    q2 = sin * i + cos * q
    yv = y[..., 0:1]
    x = jnp.concatenate([
        yv + 0.956 * i2 + 0.621 * q2,
        yv - 0.272 * i2 - 0.647 * q2,
        yv - 1.106 * i2 + 1.703 * q2,
    ], axis=-1)
    return x


def pi1(key, x):
    """Horizontal flip p=.5 -> grayscale p=.2. x: (B,H,W,3) in [0,1]."""
    k1, k2 = jax.random.split(key)
    x = _maybe(k1, 0.5, lambda im: im[:, :, ::-1, :], x)
    x = _maybe(k2, 0.2, _grayscale, x)
    return x


def pi2(key, x):
    """Color jitter (range .4) p=.8 -> grayscale p=.4."""
    k1, k2, k3 = jax.random.split(key, 3)
    jittered = _color_jitter(k2, x)
    do = jax.random.bernoulli(k1, 0.8, (x.shape[0],))
    x = jnp.where(do[:, None, None, None], jittered, x)
    x = _maybe(k3, 0.4, _grayscale, x)
    return jnp.clip(x, 0.0, 1.0)


# --------------------------------------------------------------------------
# token views (DT-SSL for sequence architectures)
# --------------------------------------------------------------------------

def token_view(key, tokens, mask_id: int, drop_p: float = 0.15):
    """Stochastic masking view of a token batch (B, S)."""
    drop = jax.random.bernoulli(key, drop_p, tokens.shape)
    return jnp.where(drop, mask_id, tokens)


# --------------------------------------------------------------------------
# MoCo / FedCo machinery
# --------------------------------------------------------------------------

class MoCoState(NamedTuple):
    key_params: object      # momentum (EMA) encoder params
    queue: jnp.ndarray      # (K, D) L2-normalized negatives
    ptr: jnp.ndarray        # scalar int32 — ring pointer


def init_moco_state(params, queue_len: int, dim: int, key) -> MoCoState:
    q = jax.random.normal(key, (queue_len, dim), jnp.float32)
    q = q / jnp.linalg.norm(q, axis=-1, keepdims=True)
    return MoCoState(key_params=jax.tree.map(jnp.asarray, params),
                     queue=q, ptr=jnp.zeros((), jnp.int32))


def momentum_update(key_params, query_params, m: float = 0.99):
    """EMA key-encoder update (MoCo)."""
    return jax.tree.map(lambda kp, qp: m * kp + (1 - m) * qp.astype(kp.dtype),
                        key_params, query_params)


def queue_push(state: MoCoState, keys: jnp.ndarray) -> MoCoState:
    """Ring-buffer enqueue of a batch of k-vectors (B, D)."""
    K = state.queue.shape[0]
    B = keys.shape[0]
    idx = (state.ptr + jnp.arange(B)) % K
    q = state.queue.at[idx].set(keys.astype(state.queue.dtype))
    return state._replace(queue=q, ptr=(state.ptr + B) % K)


def fedco_merge_queues(global_queue, client_keys_list):
    """FedCo: RSU concatenates uploaded k-value batches into the global
    queue (newest first), truncated to the global queue length.

    This is exactly the step FLSimCo criticizes: mixing k-values from
    different encoders breaks MoCo's negative-key consistency, and the
    uploads themselves leak reconstructable representations.
    """
    K = global_queue.shape[0]
    allk = jnp.concatenate(list(client_keys_list) + [global_queue], axis=0)
    return allk[:K]
