"""Explicit federated-learning state — the `FLState` the pure round API
threads (DESIGN.md §3).

The FLSimCo loop is a state machine: RSU model, PRNG streams (one jax
key for velocities/augmentations, one host `numpy.random.RandomState`
for cohort sampling and batch indices), the round counter, per-topology
vehicle state (ring-road positions, per-RSU models, sync statistics) and
per-client-algorithm state (FedCo's key-encoder tree + global negative
queue). `FLState` captures ALL of it as one immutable value, so

    state, rec = run_round(state, scenario)      # core/scenario.py

is pure: same state in -> same state out, nothing hidden in a trainer
object. That is what makes pause-at-round-k + `checkpoint/store.py`
save/restore bit-identical to an uninterrupted run (tests/test_state.py).

`FLState.to_tree()` / `FLState.from_tree()` convert to/from a plain
dict/list pytree of arrays — the payload `checkpoint.store.save` writes
and `restore(path)` reconstructs structurally (no example tree needed).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.core.mobility import BLUR_KMH_100


def resolve_fedco_alias(aggregator, client):
    """Normalize the legacy ``aggregator="fedco"`` spelling.

    Historically "fedco" was accepted as an *aggregator* name meaning
    "FedCo client algorithm aggregated with FedAvg". Both `FLConfig`
    and `Scenario` accept the old spelling; this is the one place that
    resolves it into the two registries (DESIGN.md deviation list), so
    the conflict rule cannot drift between entry points. Returns the
    (aggregator, client) pair unchanged unless aggregator == "fedco".
    """
    if aggregator != "fedco":
        return aggregator, client
    if client not in (None, "fedco"):
        raise ValueError(
            "aggregator='fedco' is a legacy alias for "
            "client='fedco', aggregator='fedavg' and conflicts "
            f"with explicit client={client!r}; pick one spelling")
    return "fedavg", "fedco"


@dataclass(frozen=True)
class FLConfig:
    n_vehicles: int = 95          # fleet size (Table 1)
    vehicles_per_round: int = 5   # N_r (Fig. 5: 5 or 10)
    local_iters: int = 1          # local SGD iterations per round
    batch_size: int = 512         # Table 1 / Sec. 5.2
    rounds: int = 150             # R^max
    lr: float = 0.9               # Table 1 (cosine annealed)
    momentum: float = 0.9
    weight_decay: float = 5e-4
    tau_alpha: float = 0.1
    tau_beta: float = 1.0
    aggregator: str = "flsimco"   # any AGGREGATORS name (core/aggregation.py)
    client: Optional[str] = None  # any CLIENT_UPDATES name (core/clients.py);
                                  # None selects the default, "dtssl"
    blur_threshold: float = BLUR_KMH_100   # in BLUR units (Eq. 2), not m/s
    moco_momentum: float = 0.99   # FedCo key-encoder EMA (Table 1)
    queue_len: int = 4096         # FedCo global queue (Sec. 5.2)
    feature_dim: int = 128
    normalize_weights: bool = True
    codec: str = "identity"       # any CODECS name (comms/codecs.py):
                                  # how model trees cross the V2I link
    seed: int = 0

    def __post_init__(self):
        # legacy spelling: aggregator="fedco" meant "FedCo client algorithm
        # aggregated with FedAvg" — `resolve_fedco_alias` normalizes it
        # into the two registries and rejects a conflicting explicit client
        aggregator, client = resolve_fedco_alias(self.aggregator, self.client)
        if aggregator != self.aggregator:
            object.__setattr__(self, "aggregator", aggregator)
        if client != self.client:
            object.__setattr__(self, "client", client)
        if self.client is None:
            object.__setattr__(self, "client", "dtssl")
        # deferred imports: the registries live in modules that import
        # FLConfig, so resolving them here (call time) breaks the cycle
        from repro.comms.codecs import CODECS
        from repro.core.aggregation import AGGREGATORS
        from repro.core.clients import CLIENT_UPDATES
        if self.aggregator not in AGGREGATORS:
            raise ValueError(
                f"unknown aggregator {self.aggregator!r}; valid: "
                f"{sorted(AGGREGATORS)}")
        if self.client not in CLIENT_UPDATES:
            raise ValueError(
                f"unknown client update {self.client!r}; valid: "
                f"{sorted(CLIENT_UPDATES)}")
        if self.codec not in CODECS:
            raise ValueError(
                f"unknown codec {self.codec!r}; valid: {sorted(CODECS)}")


# --------------------------------------------------------------------------
# host RNG <-> pytree
# --------------------------------------------------------------------------

def pack_host_rng(rng: np.random.RandomState) -> dict:
    """Serialize a `RandomState` into a pytree of arrays (checkpointable)."""
    name, keys, pos, has_gauss, cached = rng.get_state(legacy=True)
    assert name == "MT19937", name
    return {"mt_keys": np.asarray(keys, np.uint32),
            "mt_pos": np.int64(pos),
            "has_gauss": np.int64(has_gauss),
            "cached_gaussian": np.float64(cached)}


def unpack_host_rng(packed: dict) -> np.random.RandomState:
    """Rebuild the `RandomState` a `pack_host_rng` snapshot described."""
    rng = np.random.RandomState()
    rng.set_state(("MT19937",
                   np.asarray(packed["mt_keys"], np.uint32),
                   int(packed["mt_pos"]),
                   int(packed["has_gauss"]),
                   float(packed["cached_gaussian"])))
    return rng


# --------------------------------------------------------------------------
# FLState
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FLState:
    """One immutable snapshot of the federated state machine.

    global_tree   RSU/regional model pytree ({"params", "state"})
    key           jax PRNG key (velocities, augmentations, client keys)
    host_rng      packed numpy RandomState (cohort + batch-index draws);
                  see pack_host_rng — NOT shared with the jax stream, so
                  two runs built from the same FLState draw the same
                  cohorts (the old trainer hid this in `self.rng`)
    round         next round index (drives the cosine LR schedule)
    topo          per-topology state dict ({} for SingleRSU/MultiRSU;
                  positions/rsu_models/sync stats for HandoverMultiRSU)
    client_state  per-client-algorithm state (None for DT-SSL; key_tree +
                  queue for FedCo)
    comms         per-codec comms state (None for stateless codecs; the
                  error-feedback residual for delta_int8 — see
                  comms/codecs.py)
    """

    global_tree: Any
    key: Any
    host_rng: dict
    round: int = 0
    topo: dict = field(default_factory=dict)
    client_state: Optional[dict] = None
    comms: Optional[dict] = None

    def replace(self, **kw) -> "FLState":
        return dataclasses.replace(self, **kw)

    # -- checkpoint payload -------------------------------------------------

    def to_tree(self) -> dict:
        """Plain dict/list pytree of arrays — what checkpoint.store writes."""
        return {"global_tree": self.global_tree,
                "key": self.key,
                "host_rng": dict(self.host_rng),
                "round": np.int64(self.round),
                "topo": self.topo,
                "client_state": self.client_state,
                "comms": self.comms}

    @classmethod
    def from_tree(cls, tree: dict) -> "FLState":
        topo = dict(tree.get("topo") or {})
        if "positions" in topo:
            topo["positions"] = np.asarray(topo["positions"])
        for k in ("blur_sum", "upload_count"):
            if k in topo:
                topo[k] = np.asarray(topo[k])
        if "rsu_models" in topo:
            topo["rsu_models"] = tuple(topo["rsu_models"])
        cs = tree.get("client_state")
        comms = tree.get("comms")
        return cls(global_tree=tree["global_tree"],
                   key=tree["key"],
                   host_rng={k: np.asarray(v)
                             for k, v in tree["host_rng"].items()},
                   round=int(tree["round"]),
                   topo=topo,
                   client_state=dict(cs) if cs else None,
                   comms=dict(comms) if comms else None)
