"""RSU topologies — pluggable round orchestration for `FederatedTrainer`.

The paper's FLSimCo loop (Sec. 4) assumes a single RSU, yet its own
motivation — vehicles at high velocity — means clients cross RSU coverage
boundaries mid-training. This module factors the *shape of a round* out of
the trainer into a `Topology` strategy (DESIGN.md §3):

  SingleRSU         paper-exact Steps 2-4: one RSU, one cohort, one
                    host-side aggregation (any scheme in the registry).
  MultiRSU          N RSUs under one regional server. Each RSU trains its
                    cohort as a vmapped batch and aggregates locally
                    (Eq. 11), then the region merges the RSU models —
                    `aggregate_hierarchical` on host, or the
                    `two_stage_weighted_psum` collective when a
                    (pod, data) mesh is available. With n_rsus=1 this
                    reduces exactly to SingleRSU (tests/test_topology.py).
  HandoverMultiRSU  MultiRSU plus vehicle motion: per-RSU models persist
                    across rounds, vehicles hold positions on a circular
                    road (`MobilityModel.init_positions` /
                    `advance_positions`) and download from the RSU covering
                    their position at round start. Positions advance during
                    local training; a vehicle that ends the round under a
                    different RSU uploads *there* (a handover), and the
                    receiving RSU discounts that stale upload's Eq.-11
                    weight by `stale_discount` because it was trained from
                    another RSU's model. Every `sync_every` rounds the
                    region hierarchically merges the RSU models.

All three funnel their weighted sums through
`core.aggregation._weighted_tree_sum`, i.e. the fused Pallas `wagg` kernel
on TPU (tree-map fallback off-TPU; `wagg_backend("interpret")` forces the
kernel anywhere).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import aggregation as agg
from repro.core.hierarchical import (aggregate_hierarchical,
                                     two_stage_weighted_psum)


class Topology:
    """Strategy object: owns the structure of one federated round.

    `bind(trainer)` is called once from the trainer constructor (validate
    the config, initialize topology state); `run_round(trainer, r)` runs
    Steps 2-4 for round `r`, updates `trainer.global_tree`, and returns the
    round record (the trainer appends it to `history`).
    """

    name = "base"

    def bind(self, trainer) -> None:
        pass

    def run_round(self, trainer, r: int, parallel: bool = True) -> dict:
        raise NotImplementedError


class SingleRSU(Topology):
    """Paper-exact FLSimCo: one RSU aggregating one sampled cohort."""

    name = "single"

    def run_round(self, trainer, r: int, parallel: bool = True) -> dict:
        cfg = trainer.cfg
        ids, velocities = trainer._sample_round()
        lr = trainer.lr_fn(r)
        trainer.key, *cks = jax.random.split(trainer.key, len(ids) + 1)
        if cfg.aggregator == "fedco":
            rec = trainer._round_fedco(r, ids, velocities, cks, lr)
            rec["topology"] = self.name
            return rec
        client_trees, losses = trainer._run_cohort(
            trainer.global_tree, ids, velocities, cks, lr, parallel)
        blur = trainer.mobility.blur_level(velocities)
        trainer.global_tree = trainer._host_aggregate(
            client_trees, velocities, blur)
        return {"round": r, "loss": float(np.mean(losses)),
                "velocities": np.asarray(velocities).tolist(),
                "lr": float(lr), "topology": self.name}


def _require_flsimco(trainer, name: str) -> None:
    if trainer.cfg.aggregator != "flsimco":
        raise ValueError(
            f"{name} implements the hierarchical Eq.-11 (blur-weighted) "
            f"extension and requires aggregator='flsimco'; got "
            f"{trainer.cfg.aggregator!r}. Run other schemes under SingleRSU.")
    if not trainer.cfg.normalize_weights:
        raise ValueError(
            f"{name} always normalizes Eq.-11 weights (DESIGN.md deviation "
            f"#2); normalize_weights=False would break the "
            f"MultiRSU(1) == SingleRSU equivalence. Use SingleRSU for the "
            f"unnormalized literal form.")


class MultiRSU(Topology):
    """N RSUs + regional server, no motion: hierarchical Eq. 11.

    The sampled cohort is dealt round-robin across RSUs; each RSU runs its
    vehicles as one vmapped batch. Aggregation is two-level: Eq.-11 within
    each RSU, then blur-weighted (optionally vehicle-count-scaled) across
    RSU models — `aggregate_hierarchical` on host, or the
    `two_stage_weighted_psum` collective over a (pod=n_rsus, data=cohort)
    mesh when `mesh_aggregate=True` and enough devices exist.
    """

    name = "multi"

    def __init__(self, n_rsus: int = 2, count_scaled: bool = True,
                 mesh_aggregate: bool = False):
        if n_rsus < 1:
            raise ValueError("n_rsus must be >= 1")
        self.n_rsus = n_rsus
        self.count_scaled = count_scaled
        self.mesh_aggregate = mesh_aggregate

    def bind(self, trainer) -> None:
        _require_flsimco(trainer, "MultiRSU")
        if self.mesh_aggregate:
            # fail before any training work, not after the cohort has run
            n = trainer.cfg.vehicles_per_round
            if n % self.n_rsus:
                raise ValueError(
                    f"mesh_aggregate needs equal per-RSU cohorts: "
                    f"vehicles_per_round={n} not divisible by "
                    f"n_rsus={self.n_rsus}")
            if jax.device_count() < n:
                raise ValueError(
                    f"mesh_aggregate needs {n} devices "
                    f"({self.n_rsus} RSUs x {n // self.n_rsus} vehicles); "
                    f"have {jax.device_count()}")

    def run_round(self, trainer, r: int, parallel: bool = True) -> dict:
        ids, velocities = trainer._sample_round()
        lr = trainer.lr_fn(r)
        trainer.key, *cks = jax.random.split(trainer.key, len(ids) + 1)
        blur = trainer.mobility.blur_level(velocities)
        # draw every batch in round order BEFORE partitioning: the host RNG
        # is sequential, so this keeps MultiRSU(1) bit-identical to SingleRSU
        batches = jnp.stack([trainer._client_batch(c, v)
                             for c, v in zip(ids, velocities)])
        assign = np.arange(len(ids)) % self.n_rsus
        groups, blur_groups, losses, sizes = [], [], [], []
        for rsu in range(self.n_rsus):
            sel = np.where(assign == rsu)[0]
            if sel.size == 0:
                continue
            trees, ls = trainer._run_cohort(
                trainer.global_tree, ids[sel], velocities[sel],
                [cks[i] for i in sel], lr, parallel, batches=batches[sel])
            groups.append(trees)
            blur_groups.append(blur[sel])
            losses.extend(ls)
            sizes.append(int(sel.size))
        if self.mesh_aggregate:
            trainer.global_tree = self._mesh_aggregate(groups, blur_groups)
        else:
            trainer.global_tree = aggregate_hierarchical(
                groups, blur_groups, self.count_scaled)
        return {"round": r, "loss": float(np.mean(losses)),
                "velocities": np.asarray(velocities).tolist(),
                "lr": float(lr), "topology": self.name, "rsu_sizes": sizes}

    def _mesh_aggregate(self, groups: Sequence, blur_groups: Sequence):
        """Region merge as the two-stage collective over a (pod, data) mesh.

        Requires equal cohort sizes and n_rsus * cohort_size devices — the
        mesh *is* the topology here (one device slice per vehicle).
        """
        sizes = {len(g) for g in groups}
        if len(sizes) != 1:
            raise ValueError("mesh_aggregate needs equal per-RSU cohorts; "
                             f"got sizes {sorted(len(g) for g in groups)}")
        m = sizes.pop()
        need = len(groups) * m
        if jax.device_count() < need:
            raise ValueError(
                f"mesh_aggregate needs {need} devices "
                f"({len(groups)} RSUs x {m} vehicles); "
                f"have {jax.device_count()}")
        mesh = jax.make_mesh((len(groups), m), ("pod", "data"))
        flat = [t for g in groups for t in g]                  # rsu-major
        stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *flat)
        blur = jnp.concatenate([jnp.asarray(b, jnp.float32).reshape(-1)
                                for b in blur_groups])

        def per_cohort(tree, L):
            return two_stage_weighted_psum(
                jax.tree.map(lambda x: x[0], tree), L[0],
                count_scaled=self.count_scaled)

        from repro.compat import shard_map
        fn = shard_map(per_cohort, mesh=mesh,
                       in_specs=(P(("pod", "data")), P(("pod", "data"))),
                       out_specs=P(), check=False)
        return fn(stacked, blur)


class HandoverMultiRSU(Topology):
    """MultiRSU with persistent per-RSU models and vehicle motion.

    Road model: a ring road of length n_rsus * rsu_range; RSU r covers
    [r*rsu_range, (r+1)*rsu_range). Each round every vehicle's position
    advances by v * round_duration (positions wrap), so a participant can
    download from RSU A and — after training — upload to RSU B. Such stale
    uploads get their Eq.-11 weight multiplied by `stale_discount` before
    renormalization. RSUs that receive no uploads keep their model.
    Every `sync_every` rounds the regional server merges the RSU models
    with blur-weighted, upload-count-scaled level-2 weights (accumulated
    since the last sync) and redistributes the merged model.
    """

    name = "handover"

    def __init__(self, n_rsus: int = 2, rsu_range: float = 1000.0,
                 round_duration: float = 20.0, stale_discount: float = 0.5,
                 sync_every: int = 5, count_scaled: bool = True):
        if n_rsus < 1:
            raise ValueError("n_rsus must be >= 1")
        if not 0.0 <= stale_discount <= 1.0:
            raise ValueError("stale_discount must be in [0, 1]")
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        self.n_rsus = n_rsus
        self.rsu_range = rsu_range
        self.road_length = n_rsus * rsu_range
        self.round_duration = round_duration
        self.stale_discount = stale_discount
        self.sync_every = sync_every
        self.count_scaled = count_scaled
        self.positions: Optional[np.ndarray] = None
        self.rsu_models: list = []
        self._blur_sum = np.zeros(n_rsus)
        self._upload_count = np.zeros(n_rsus)

    def bind(self, trainer) -> None:
        _require_flsimco(trainer, "HandoverMultiRSU")
        trainer.key, kp = jax.random.split(trainer.key)
        self.positions = np.asarray(trainer.mobility.init_positions(
            kp, trainer.cfg.n_vehicles, self.road_length))
        self.rsu_models = [trainer.global_tree] * self.n_rsus
        # rebinding to a fresh trainer must not carry sync statistics over
        self._blur_sum[:] = 0.0
        self._upload_count[:] = 0.0

    def rsu_index(self, positions) -> np.ndarray:
        return (np.floor_divide(np.asarray(positions), self.rsu_range)
                .astype(np.int64) % self.n_rsus)

    def run_round(self, trainer, r: int, parallel: bool = True) -> dict:
        cfg, mob = trainer.cfg, trainer.mobility
        n = cfg.vehicles_per_round
        ids = trainer.rng.choice(cfg.n_vehicles, size=n, replace=False)
        # one velocity draw per vehicle per round, used for both the blur
        # level of the participants' captures and the whole fleet's motion
        trainer.key, kv = jax.random.split(trainer.key)
        fleet_v = mob.sample(kv, cfg.n_vehicles)
        velocities = jnp.take(fleet_v, jnp.asarray(ids))
        lr = trainer.lr_fn(r)
        trainer.key, *cks = jax.random.split(trainer.key, n + 1)

        # Step 2: download from the RSU covering the round-start position
        down = self.rsu_index(self.positions[ids])
        client_trees: list = [None] * n
        losses: list = [0.0] * n
        for rsu in range(self.n_rsus):
            sel = np.where(down == rsu)[0]
            if sel.size == 0:
                continue
            trees, ls = trainer._run_cohort(
                self.rsu_models[rsu], ids[sel], velocities[sel],
                [cks[i] for i in sel], lr, parallel)
            for j, i in enumerate(sel):
                client_trees[i] = trees[j]
                losses[i] = ls[j]

        # motion during the round: everyone moves, positions wrap
        self.positions = np.asarray(mob.advance_positions(
            self.positions, fleet_v, self.round_duration, self.road_length))

        # Step 3-4: upload to the RSU now covering the vehicle
        up = self.rsu_index(self.positions[ids])
        stale = up != down
        blur = np.asarray(mob.blur_level(velocities))
        upload_sizes = []
        for rsu in range(self.n_rsus):
            sel = np.where(up == rsu)[0]
            upload_sizes.append(int(sel.size))
            if sel.size == 0:
                continue
            w = np.asarray(agg.flsimco_weights(jnp.asarray(blur[sel])))
            w = w * np.where(stale[sel], self.stale_discount, 1.0)
            s = w.sum()
            # all uploads stale with stale_discount=0: fall back to uniform
            # rather than zeroing the RSU model
            w = w / s if s > 1e-12 else np.full_like(w, 1.0 / len(w))
            self.rsu_models[rsu] = agg._weighted_tree_sum(
                [client_trees[i] for i in sel], w)
            self._blur_sum[rsu] += float(blur[sel].sum())
            self._upload_count[rsu] += sel.size

        synced = (r + 1) % self.sync_every == 0
        if synced:
            trainer.global_tree = self._region_sync(mob)
        # between syncs trainer.global_tree keeps the last merged model;
        # RSU models stay divergent until sync (region_view() merges on
        # demand without paying an n_rsus-model sum every round)
        return {"round": r, "loss": float(np.mean(losses)),
                "velocities": np.asarray(velocities).tolist(),
                "lr": float(lr), "topology": self.name,
                "rsu_sizes": upload_sizes,
                "n_handovers": int(stale.sum()), "synced": synced}

    def region_view(self):
        """Uniform merge of the current per-RSU models — an evaluation
        snapshot between syncs; does not touch topology state."""
        return agg.aggregate_fedavg(self.rsu_models)

    def _region_sync(self, mob):
        """Level-2 merge of the per-RSU models (Eq. 11 over mean blur,
        optionally scaled by uploads since the last sync)."""
        counts = self._upload_count
        mean_blur = np.where(
            counts > 0, self._blur_sum / np.maximum(counts, 1.0),
            float(mob.blur_level(mob.mu)))   # no uploads: prior mean blur
        W = np.asarray(agg.flsimco_weights(jnp.asarray(mean_blur,
                                                       jnp.float32)))
        if self.count_scaled:
            W = W * counts
        s = W.sum()
        W = W / s if s > 1e-12 else np.full_like(W, 1.0 / len(W))
        merged = agg._weighted_tree_sum(self.rsu_models, W)
        self.rsu_models = [merged] * self.n_rsus
        self._blur_sum[:] = 0.0
        self._upload_count[:] = 0.0
        return merged


TOPOLOGIES = {
    "single": SingleRSU,
    "multi": MultiRSU,
    "handover": HandoverMultiRSU,
}
