"""RSU topologies — pure round orchestration over an explicit `FLState`.

The paper's FLSimCo loop (Sec. 4) assumes a single RSU, yet its own
motivation — vehicles at high velocity — means clients cross RSU coverage
boundaries mid-training. This module factors the *shape of a round* into
a `Topology` strategy (DESIGN.md §3). A topology is a *stateless* config
object: everything that changes round to round (positions, per-RSU
models, sync statistics) lives in `FLState.topo`, so

    state, rec = topology.run_round(state, scenario)

is pure — same state in, same state out, nothing mutated.

  SingleRSU         paper-exact Steps 2-4: one RSU, one cohort, one
                    host-side aggregation (any ``AGGREGATORS`` scheme,
                    any ``CLIENT_UPDATES`` algorithm).
  MultiRSU          N RSUs under one regional server. Each RSU trains its
                    cohort as a vmapped batch and aggregates locally
                    (Eq. 11), then the region merges the RSU models —
                    `aggregate_hierarchical` on host, or the
                    `two_stage_weighted_psum` collective when a
                    (pod, data) mesh is available. With n_rsus=1 this
                    reduces exactly to SingleRSU (tests/test_topology.py).
  HandoverMultiRSU  MultiRSU plus vehicle motion: per-RSU models persist
                    across rounds in `FLState.topo`, vehicles hold
                    positions on a circular road and download from the
                    RSU covering their position at round start. Positions
                    advance during local training; a vehicle that ends
                    the round under a different RSU uploads *there* (a
                    handover), and the receiving RSU discounts that stale
                    upload's Eq.-11 weight by `stale_discount`. Every
                    `sync_every` rounds the region merges the RSU models.

Rounds move cohorts between layers as device-resident `CohortBatch`es
(core/cohort.py): the client layer returns its vmapped result stacked,
aggregation consumes the stacked leaves + validity mask directly, and
the per-client payload (losses) crosses to host exactly once per round,
in the single `jax.device_get` that builds the round record (handover
additionally fetches a few SMALL per-round arrays — positions, blur,
per-RSU weights — whose sizes are O(cohort), not O(model)). Handover pads each per-RSU group to a bucketed
(power-of-two) size so its variable-size cohorts run the vmapped path
with a bounded set of compiles, bit-exact with the sequential reference
(tests/test_topology.py). All three topologies funnel their weighted
sums through `core.aggregation._weighted_stacked_sum`, i.e. the fused
Pallas `wagg` kernel on TPU (tree-map fallback off-TPU;
`wagg_backend("interpret")` forces the kernel anywhere).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.comms.codecs import roundtrip_cohort
from repro.core import aggregation as agg
from repro.core.clients import CLIENT_UPDATES
from repro.core.cohort import CohortBatch, bucket_size
from repro.core.hierarchical import (aggregate_hierarchical,
                                     sharded_hierarchical)
from repro.core.mobility import apply_motion_blur
from repro.core.state import FLConfig, FLState, pack_host_rng, unpack_host_rng


# --------------------------------------------------------------------------
# shared round machinery (host RNG draws in a fixed, documented order)
# --------------------------------------------------------------------------

def _batch_indices(rng, data_len: int, cfg) -> np.ndarray:
    """One client's batch indices, drawn from the *host* RNG stream.

    Fixed batch size across clients (vmapped cohorts need equal shapes);
    small clients sample with replacement. This is the ONE place batch
    indices come from: the eager round path and the compiled campaign
    engine (core/engine.py) both draw through here, which is what makes
    the engine's pre-drawn schedule arrays bitwise-identical to the live
    draws (tests/test_engine.py)."""
    return rng.choice(data_len, size=cfg.batch_size,
                      replace=data_len < cfg.batch_size)


def _client_images(scenario, cid: int, idx, velocity):
    """Materialize one client's batch from pre-drawn indices (consumes
    no RNG — blur is a pure function of the velocity draw)."""
    # analysis: allow=retrace-fresh-array -- the per-round batch upload
    # IS the data path; indices are fresh draws, nothing to cache
    images = jnp.asarray(scenario.data[cid][idx])
    if scenario.blur_images:
        images = apply_motion_blur(images, velocity,
                                   scenario.mobility.camera_const)
    return images


def _client_batch(rng, scenario, cid: int, velocity):
    """Draw + materialize one client's training batch."""
    idx = _batch_indices(rng, len(scenario.data[cid]), scenario.cfg)
    return _client_images(scenario, cid, idx, velocity)


def _draw_batches(rng, scenario, ids, velocities):
    """Batches for a cohort, drawn in `ids` order (the host RNG is a
    sequential stream, so draw order matters for cross-topology
    equivalence — see MultiRSU.run_round)."""
    return jnp.stack([_client_batch(rng, scenario, c, v)
                      for c, v in zip(ids, velocities)])


def _cohort_plan(rng, key, rnd: int, scenario):
    """Training-independent round preamble: cohort ids from the host RNG,
    velocities + per-client keys from the jax chain, LR from the cosine
    schedule. Takes the RNG streams EXPLICITLY (not an FLState) so the
    compiled campaign engine can replay the identical draw sequence K
    rounds ahead of execution. Returns (ids, velocities, lr, key, cks).
    """
    cfg, mob = scenario.cfg, scenario.mobility
    ids = rng.choice(cfg.n_vehicles, size=cfg.vehicles_per_round,
                     replace=False)
    key, kv = jax.random.split(key)
    velocities = mob.sample(kv, len(ids))
    lr = scenario.lr_fn(rnd)
    key, *cks = jax.random.split(key, len(ids) + 1)
    return ids, velocities, lr, key, cks


def _sample_cohort(state, scenario):
    """Round preamble shared by SingleRSU and MultiRSU.

    The draw ORDER (host-RNG cohort ids -> jax velocity key -> per-client
    keys) is load-bearing: the MultiRSU(1) == SingleRSU bit-exactness
    guarantee requires both topologies to consume both RNG streams
    identically, so the sequence lives in exactly one place
    (`_cohort_plan`, also the engine's schedule source).
    Returns (rng, ids, velocities, lr, key, client_keys).
    """
    rng = unpack_host_rng(state.host_rng)
    ids, velocities, lr, key, cks = _cohort_plan(rng, state.key,
                                                 state.round, scenario)
    return rng, ids, velocities, lr, key, cks


def _region_sync_weights(mob, blur_sum, upload_count,
                         count_scaled: bool) -> np.ndarray:
    """Level-2 sync weights (Eq. 11 over per-RSU mean blur since the last
    sync, optionally scaled by upload counts). Training-independent —
    shared by the eager round and the engine's schedule precompute."""
    counts = np.asarray(upload_count, np.float64)
    mean_blur = np.where(
        counts > 0, np.asarray(blur_sum, np.float64) / np.maximum(counts, 1.0),
        float(mob.blur_level(mob.mu)))   # no uploads: prior mean blur
    W = np.asarray(agg.flsimco_weights(jnp.asarray(mean_blur, jnp.float32)))
    if count_scaled:
        W = W * counts
    s = W.sum()
    return W / s if s > 1e-12 else np.full_like(W, 1.0 / len(W))


def _record_fetch(losses, velocities, lr):
    """The one per-round device transfer: fetch the whole record payload
    (losses + velocities + lr) in a single `device_get`. Losses stay
    device-resident inside the `CohortBatch` until here; the mean is
    taken in float64 on host, matching the old per-client `float(loss)`
    record values bit for bit. `device_get` passes host (numpy) inputs
    through untouched, so callers hand over whatever mix the round
    produced — no re-upload, no second sync for the lr scalar.
    """
    # analysis: sanctioned-sync -- the designed once-per-round record fetch
    losses_h, v_h, lr_h = jax.device_get((losses, velocities, lr))
    # analysis: sanctioned-sync -- host-side views of the fetched payload
    return (np.asarray(losses_h, np.float64),
            np.asarray(v_h).tolist(), float(lr_h))


class Topology:
    """Strategy object: owns the structure of one federated round.

    Topologies hold only static configuration (n_rsus, ranges, ...);
    round-to-round state lives in `FLState.topo`, produced by
    `init_state` and threaded through `run_round`.

    validate(cfg)                      fail fast on unsupported configs
    init_state(cfg, mobility,
               global_tree, key)       -> (topo_state dict, new key)
    run_round(state, scenario)         -> (new FLState, round record)
    """

    name = "base"

    def validate(self, cfg: FLConfig) -> None:
        pass

    def signature(self) -> dict:
        """Static topology parameters, JSON-able — part of the checkpoint
        experiment fingerprint (checkpoint/store.py) and the engine's
        compiled-callable cache key (core/engine.py). The name alone is
        not enough: a handover checkpoint taken under n_rsus=2 must not
        resume under n_rsus=3."""
        return {"name": self.name}

    def init_state(self, cfg: FLConfig, mobility, global_tree, key):
        return {}, key

    def run_round(self, state: FLState, scenario, parallel: bool = True):
        raise NotImplementedError


class SingleRSU(Topology):
    """Paper-exact FLSimCo: one RSU aggregating one sampled cohort."""

    name = "single"

    def run_round(self, state: FLState, scenario, parallel: bool = True):
        cfg, mob = scenario.cfg, scenario.mobility
        rng, ids, velocities, lr, key, cks = _sample_cohort(state, scenario)
        client = CLIENT_UPDATES[cfg.client]
        batches = _draw_batches(rng, scenario, ids, velocities)
        cohort, uploads = client.run_cohort(
            cfg, state.global_tree, state.client_state, batches, cks, lr,
            parallel)
        cohort = cohort.with_stats(velocities=velocities,
                                   blur=mob.blur_level(velocities))
        # comms tier: the cohort the RSU aggregates is what survived the
        # V2I link (encode -> decode against the broadcast base model);
        # identity short-circuits, the lossless delta codec is bitwise
        cohort, comms = roundtrip_cohort(cfg, cohort, state.global_tree,
                                         state.comms)
        new_tree = agg.AGGREGATORS[cfg.aggregator](cohort, cfg)
        new_cs = client.finalize(cfg, state.client_state, new_tree, uploads)
        losses, vels, lr_h = _record_fetch(cohort.valid_losses,
                                           cohort.valid_velocities, lr)
        rec = {"round": state.round, "loss": float(np.mean(losses)),
               "velocities": vels,
               "lr": lr_h, "topology": self.name}
        return state.replace(global_tree=new_tree, key=key,
                             host_rng=pack_host_rng(rng),
                             round=state.round + 1,
                             client_state=new_cs, comms=comms), rec


def _require_flsimco(cfg: FLConfig, name: str) -> None:
    if cfg.aggregator != "flsimco":
        raise ValueError(
            f"{name} implements the hierarchical Eq.-11 (blur-weighted) "
            f"extension and requires aggregator='flsimco'; got "
            f"{cfg.aggregator!r}. Run other schemes under SingleRSU.")
    if not cfg.normalize_weights:
        raise ValueError(
            f"{name} always normalizes Eq.-11 weights (DESIGN.md deviation "
            f"#2); normalize_weights=False would break the "
            f"MultiRSU(1) == SingleRSU equivalence. Use SingleRSU for the "
            f"unnormalized literal form.")


class MultiRSU(Topology):
    """N RSUs + regional server, no motion: hierarchical Eq. 11.

    The sampled cohort is dealt round-robin across RSUs; each RSU runs its
    vehicles as one vmapped batch. Aggregation is two-level: Eq.-11 within
    each RSU, then blur-weighted (optionally vehicle-count-scaled) across
    RSU models — `sharded_hierarchical` over a cached (pod=n_rsus, data=d)
    cohort mesh BY DEFAULT whenever >1 device is visible and the cohort
    splits evenly (mesh_aggregate=None auto; the "exact" reduction is
    bit-exact with the host path), `aggregate_hierarchical` on host
    otherwise. mesh_aggregate=True forces the mesh (actionable error when
    infeasible); False pins the host path. On a multi-device mesh the
    whole round shards: client blocks run under shard_map too
    (float-close vs the single-device vmap width — DESIGN.md §Sharded
    cohorts).
    """

    name = "multi"

    def __init__(self, n_rsus: int = 2, count_scaled: bool = True,
                 mesh_aggregate: bool | None = None,
                 mesh_reduction: str = "exact"):
        if n_rsus < 1:
            raise ValueError("n_rsus must be >= 1")
        if mesh_reduction not in ("exact", "psum"):
            raise ValueError(f"mesh_reduction {mesh_reduction!r} not in "
                             f"('exact', 'psum')")
        self.n_rsus = n_rsus
        self.count_scaled = count_scaled
        # None = AUTO (the default): shard whenever >1 device is visible
        # and the cohort splits evenly across RSUs; True forces the mesh
        # path (raising an actionable error when infeasible); False pins
        # the host path.
        self.mesh_aggregate = mesh_aggregate
        self.mesh_reduction = mesh_reduction

    def signature(self) -> dict:
        return {"name": self.name, "n_rsus": self.n_rsus,
                "count_scaled": self.count_scaled,
                "mesh_aggregate": self.mesh_aggregate,
                "mesh_reduction": self.mesh_reduction}

    def resolve_mesh(self, cfg: FLConfig):
        """The cohort mesh this topology's rounds run on, or None for the
        single-device host path. AUTO (mesh_aggregate=None) promotes the
        sharded path to the default whenever >1 device is visible and the
        cohort splits evenly; explicit True raises actionable errors
        (required vs available devices, uneven-cohort hint) instead of
        silently falling back."""
        from repro.launch.mesh import (cohort_axis_divisor, cohort_mesh,
                                       maybe_cohort_mesh)
        if self.mesh_aggregate is False:
            return None
        n = cfg.vehicles_per_round
        if n % self.n_rsus:
            if self.mesh_aggregate:   # explicit True: fail, don't fall back
                raise ValueError(
                    f"mesh_aggregate needs equal per-RSU cohorts: "
                    f"vehicles_per_round={n} not divisible by "
                    f"n_rsus={self.n_rsus} — pick n_rsus dividing the "
                    f"cohort, or mesh_aggregate=None to auto-fall-back")
            return None
        s = n // self.n_rsus
        if self.mesh_aggregate:
            return cohort_mesh(self.n_rsus,
                               cohort_axis_divisor(s, self.n_rsus))
        return maybe_cohort_mesh(self.n_rsus, s)

    def validate(self, cfg: FLConfig) -> None:
        _require_flsimco(cfg, "MultiRSU")
        # fail before any training work, not after the cohort has run
        self.resolve_mesh(cfg)

    def run_round(self, state: FLState, scenario, parallel: bool = True):
        cfg, mob = scenario.cfg, scenario.mobility
        rng, ids, velocities, lr, key, cks = _sample_cohort(state, scenario)
        blur = mob.blur_level(velocities)
        client = CLIENT_UPDATES[cfg.client]
        mesh = self.resolve_mesh(cfg)
        # draw every batch in round order BEFORE partitioning: the host RNG
        # is sequential, so this keeps MultiRSU(1) bit-identical to SingleRSU
        batches = _draw_batches(rng, scenario, ids, velocities)
        assign = np.arange(len(ids)) % self.n_rsus
        sels = [np.where(assign == rsu)[0] for rsu in range(self.n_rsus)]
        sels = [s for s in sels if s.size]
        if (mesh is not None and parallel and mesh.size > 1
                and cfg.client == "dtssl"):
            # fully sharded round: ONE rsu-major cohort, client blocks and
            # the two-level reduction both under shard_map. Client
            # execution vmaps per device block (float-close vs the
            # unsharded vmap width); the aggregation itself is bit-exact
            # with the host path (DESIGN.md §Sharded cohorts).
            perm = np.concatenate(sels)
            cohort, uploads = client.run_cohort(
                cfg, state.global_tree, state.client_state, batches[perm],
                jnp.stack([cks[i] for i in perm]), lr, parallel, mesh=mesh)
            blur_rm = blur[perm]      # blur_level already yields jnp f32
            cohort = cohort.with_stats(velocities=velocities[perm],
                                       blur=blur_rm)
            # codec rows are perm (cohort indices): EF slot = cohort
            # position, identical to the host branch's per-group slots
            cohort, comms = roundtrip_cohort(cfg, cohort,
                                             state.global_tree,
                                             state.comms, rows=perm)
            new_tree = sharded_hierarchical(
                cohort.valid_trees, blur_rm, mesh, len(sels),
                count_scaled=self.count_scaled,
                reduction=self.mesh_reduction)
            sizes = [int(s.size) for s in sels]
            losses = cohort.valid_losses   # already rsu-major
            uploads = list(uploads) if uploads else []
        else:
            comms = state.comms
            cohorts, sizes, uploads = [], [], []
            for sel in sels:
                cohort, ups = client.run_cohort(
                    cfg, state.global_tree, state.client_state,
                    batches[sel], [cks[i] for i in sel], lr, parallel)
                cohort = cohort.with_stats(velocities=velocities[sel],
                                           blur=blur[sel])
                # per-group roundtrip; the codec is row-wise, so group
                # application == full-cohort application (rows=sel keeps
                # EF slots in cohort order, matching the sharded branch)
                cohort, comms = roundtrip_cohort(cfg, cohort,
                                                 state.global_tree,
                                                 comms, rows=sel)
                cohorts.append(cohort)
                sizes.append(int(sel.size))
                if ups:
                    uploads.extend(ups)
            if mesh is not None:
                new_tree = self._mesh_aggregate(cohorts, mesh)
            else:
                new_tree = aggregate_hierarchical(
                    cohorts, count_scaled=self.count_scaled)
            losses = jnp.concatenate([c.valid_losses for c in cohorts])
        new_cs = client.finalize(cfg, state.client_state, new_tree,
                                 uploads or None)
        # losses in RSU order (matching the old list-extend order), one fetch
        losses, vels, lr_h = _record_fetch(losses, velocities, lr)
        rec = {"round": state.round, "loss": float(np.mean(losses)),
               "velocities": vels,
               "lr": lr_h, "topology": self.name, "rsu_sizes": sizes}
        return state.replace(global_tree=new_tree, key=key,
                             host_rng=pack_host_rng(rng),
                             round=state.round + 1,
                             client_state=new_cs, comms=comms), rec

    def _mesh_aggregate(self, cohorts: Sequence[CohortBatch], mesh):
        """Region merge sharded over the cached cohort mesh
        (launch/mesh.py — the old code built a fresh `jax.make_mesh`
        every round). reduction="exact" (default) is bit-exact with
        `aggregate_hierarchical`; "psum" is the blocked
        `two_stage_weighted_psum` collective (documented-float-close)."""
        sizes = {c.n for c in cohorts}
        if len(sizes) != 1:
            raise ValueError("mesh_aggregate needs equal per-RSU cohorts; "
                             f"got sizes {sorted(c.n for c in cohorts)}")
        # rsu-major stacked cohort: concatenate the already-stacked valid
        # leaves — the old list path re-stacked N separate trees here
        stacked = jax.tree.map(lambda *ls: jnp.concatenate(ls),
                               *[c.valid_trees for c in cohorts])
        blur = jnp.concatenate([c.valid_blur.astype(jnp.float32)
                                for c in cohorts])
        return sharded_hierarchical(stacked, blur, mesh, len(cohorts),
                                    count_scaled=self.count_scaled,
                                    reduction=self.mesh_reduction)


class HandoverMultiRSU(Topology):
    """MultiRSU with persistent per-RSU models and vehicle motion.

    Road model: a ring road of length n_rsus * rsu_range; RSU r covers
    [r*rsu_range, (r+1)*rsu_range). Each round every vehicle's position
    advances by v * round_duration (positions wrap), so a participant can
    download from RSU A and — after training — upload to RSU B. Such stale
    uploads get their Eq.-11 weight multiplied by `stale_discount` before
    renormalization. RSUs that receive no uploads keep their model.
    Every `sync_every` rounds the regional server merges the RSU models
    with blur-weighted, upload-count-scaled level-2 weights (accumulated
    since the last sync) and redistributes the merged model.

    Per-RSU cohort sizes change with vehicle positions every round, and
    the vmapped cohort step specializes on its size — naively that is a
    fresh XLA compile per new size, which is why this topology used to be
    stuck on the slow sequential client path. Instead each download group
    is padded to a bucketed size (`cohort.bucket_size`: the next power of
    two), so `parallel=True` (the default) runs every group vmapped with
    at most ceil(log2(vehicles_per_round)) + 1 distinct compiles; the
    padding rows replicate the last valid client, consume no RNG, and are
    masked out of every upload aggregation, making the bucketed path
    bit-exact with the sequential reference (`parallel=False`,
    tests/test_topology.py).

    Per-round vehicle state (positions, per-RSU models, sync statistics)
    lives in `FLState.topo`:

      positions      (n_vehicles,) ring-road positions
      rsu_models     tuple of n_rsus model pytrees
      blur_sum       (n_rsus,) blur accumulated since last sync
      upload_count   (n_rsus,) uploads accumulated since last sync
    """

    name = "handover"

    def __init__(self, n_rsus: int = 2, rsu_range: float = 1000.0,
                 round_duration: float = 20.0, stale_discount: float = 0.5,
                 sync_every: int = 5, count_scaled: bool = True,
                 bucketed: bool = True, mesh_shard: bool = False):
        if n_rsus < 1:
            raise ValueError("n_rsus must be >= 1")
        if not 0.0 <= stale_discount <= 1.0:
            raise ValueError("stale_discount must be in [0, 1]")
        if sync_every < 1:
            raise ValueError("sync_every must be >= 1")
        self.n_rsus = n_rsus
        self.rsu_range = rsu_range
        self.road_length = n_rsus * rsu_range
        self.round_duration = round_duration
        self.stale_discount = stale_discount
        self.sync_every = sync_every
        self.count_scaled = count_scaled
        # bucketed=False runs the vmapped step at each group's EXACT size
        # — a fresh XLA compile for every cohort size vehicle motion
        # produces. Exists so benchmarks/round_engine.py can price the
        # recompile cost bucketing removes; keep the default on.
        self.bucketed = bucketed
        # mesh_shard=True shards each download group's client execution
        # over a (pod=1, data=d) cohort mesh when >1 device is visible;
        # the per-RSU regrouping stays device-side `CohortBatch.take`
        # gathers under the sharding. Opt-in (not auto like MultiRSU):
        # the sharded vmap width differs from the single-device one, so
        # this path is float-close, not bitwise, with the bucketed
        # reference the handover tests pin.
        self.mesh_shard = mesh_shard

    def signature(self) -> dict:
        return {"name": self.name, "n_rsus": self.n_rsus,
                "rsu_range": self.rsu_range,
                "round_duration": self.round_duration,
                "stale_discount": self.stale_discount,
                "sync_every": self.sync_every,
                "count_scaled": self.count_scaled,
                "mesh_shard": self.mesh_shard}

    def validate(self, cfg: FLConfig) -> None:
        _require_flsimco(cfg, "HandoverMultiRSU")
        if cfg.client != "dtssl":
            raise ValueError(
                "HandoverMultiRSU keeps divergent per-RSU models between "
                "syncs, so client algorithms with global server state "
                f"(client={cfg.client!r}) are undefined here; use "
                "client='dtssl' or the SingleRSU/MultiRSU topologies.")

    def init_state(self, cfg: FLConfig, mobility, global_tree, key):
        key, kp = jax.random.split(key)
        positions = np.asarray(mobility.init_positions(
            kp, cfg.n_vehicles, self.road_length))
        return {"positions": positions,
                "rsu_models": tuple([global_tree] * self.n_rsus),
                "blur_sum": np.zeros(self.n_rsus),
                "upload_count": np.zeros(self.n_rsus)}, key

    def rsu_index(self, positions) -> np.ndarray:
        return (np.floor_divide(np.asarray(positions), self.rsu_range)
                .astype(np.int64) % self.n_rsus)

    def plan_round(self, rng, key, rnd: int, positions, blur_sum,
                   upload_count, scenario) -> dict:
        """Everything about one handover round that does NOT depend on
        training results: all RNG draws (in the documented order), the
        download/upload grouping, Eq.-11 upload weights with staleness
        discounts, motion, sync decision + level-2 weights, and the
        accumulator updates. `run_round` executes a plan against the
        models; the campaign engine (core/engine.py) replays K plans
        ahead of time into schedule arrays — one code path for the
        draws is what makes the two bitwise-identical.

        Mutates nothing: takes positions/blur_sum/upload_count by value
        and returns their successors in the plan dict.
        """
        cfg, mob = scenario.cfg, scenario.mobility
        # analysis: allow=host-sync-fetch -- host accumulators (copied
        # by value so the plan mutates nothing; never device-resident)
        blur_sum = np.array(blur_sum, np.float64)
        # analysis: allow=host-sync-fetch -- host accumulator copy
        upload_count = np.array(upload_count, np.float64)
        n = cfg.vehicles_per_round
        ids = rng.choice(cfg.n_vehicles, size=n, replace=False)
        # one velocity draw per vehicle per round, used for both the blur
        # level of the participants' captures and the whole fleet's motion
        key, kv = jax.random.split(key)
        fleet_v = mob.sample(kv, cfg.n_vehicles)
        # analysis: allow=retrace-fresh-array -- once-per-round schedule
        # upload (fresh host draws enter the device here by design)
        velocities = jnp.take(fleet_v, jnp.asarray(ids))
        lr = scenario.lr_fn(rnd)
        key, *cks = jax.random.split(key, n + 1)

        # Step 2 grouping: download from the RSU covering the round-start
        # position; batch indices are drawn in download-group order (the
        # host RNG is sequential) and scattered back to cohort positions
        down = self.rsu_index(positions[ids])
        down_groups = []
        idx = np.empty((n, cfg.batch_size), np.int64)
        for rsu in range(self.n_rsus):
            sel = np.where(down == rsu)[0]
            if sel.size == 0:
                continue
            for i in sel:
                idx[i] = _batch_indices(rng, len(scenario.data[ids[i]]), cfg)
            down_groups.append((rsu, sel))

        # motion during the round: everyone moves, positions wrap
        # analysis: sanctioned-sync -- plan-time fetch of O(fleet)
        # positions; handover grouping is host-side by design
        positions = np.asarray(mob.advance_positions(
            positions, fleet_v, self.round_duration, self.road_length))

        # Step 3-4 grouping: upload to the RSU now covering the vehicle,
        # stale uploads discounted before renormalization
        up = self.rsu_index(positions[ids])
        stale = up != down
        # analysis: sanctioned-sync -- plan-time fetch of O(cohort) blur
        blur = np.asarray(mob.blur_level(velocities))
        upload_sizes, uploads = [], []
        for rsu in range(self.n_rsus):
            sel = np.where(up == rsu)[0]
            upload_sizes.append(int(sel.size))
            if sel.size == 0:
                continue
            # analysis: allow=host-sync-fetch,retrace-fresh-array --
            # Eq.-11 weights on O(group) arrays; f32-on-device is the
            # bit-pinned path (tests), the round trip is the price
            w = np.asarray(agg.flsimco_weights(jnp.asarray(blur[sel])))
            w = w * np.where(stale[sel], self.stale_discount, 1.0)
            s = w.sum()
            if s <= 1e-12:
                # every upload stale with stale_discount=0: no usable
                # uploads — the RSU keeps its model (same as receiving
                # none), rather than handing the discarded uploads full
                # uniform weight
                continue
            uploads.append((rsu, sel, w / s))
            # analysis: allow=host-sync-cast -- blur is host numpy here
            blur_sum[rsu] += float(blur[sel].sum())
            upload_count[rsu] += sel.size

        synced = (rnd + 1) % self.sync_every == 0
        sync_W = None
        if synced:
            sync_W = _region_sync_weights(mob, blur_sum, upload_count,
                                          self.count_scaled)
            blur_sum = np.zeros(self.n_rsus)
            upload_count = np.zeros(self.n_rsus)
        return {"ids": ids, "idx": idx, "velocities": velocities,
                "fleet_v": fleet_v, "lr": lr, "key": key, "cks": cks,
                "down": down, "down_groups": down_groups,
                "positions": positions, "up": up, "stale": stale,
                "blur": blur, "uploads": uploads,
                "upload_sizes": upload_sizes, "synced": synced,
                "sync_W": sync_W, "blur_sum": blur_sum,
                "upload_count": upload_count}

    def run_round(self, state: FLState, scenario, parallel: bool = True):
        cfg = scenario.cfg
        rng = unpack_host_rng(state.host_rng)
        rsu_models = list(state.topo["rsu_models"])
        plan = self.plan_round(rng, state.key, state.round,
                               # analysis: allow=host-sync-fetch --
                               # positions live in host topo state
                               np.asarray(state.topo["positions"]),
                               state.topo["blur_sum"],
                               state.topo["upload_count"], scenario)
        ids, velocities, lr = plan["ids"], plan["velocities"], plan["lr"]
        client = CLIENT_UPDATES[cfg.client]

        # Step 2: each download group runs vmapped (parallel=True, the
        # default), padded to its power-of-two bucket so the set of
        # compiled cohort sizes is bounded; parallel=False is the
        # sequential reference path. Either way the group results stay
        # STACKED in CohortBatches.
        mesh = None
        if self.mesh_shard and parallel:
            from repro.launch.mesh import maybe_cohort_mesh
            mesh = maybe_cohort_mesh(1, bucket_size(cfg.vehicles_per_round))
        comms = state.comms
        group_sel, group_cohorts = [], []
        for rsu, sel in plan["down_groups"]:
            batches = jnp.stack([
                _client_images(scenario, ids[i], plan["idx"][i],
                               velocities[i]) for i in sel])
            cohort, _ = client.run_cohort(
                cfg, rsu_models[rsu], state.client_state, batches,
                [plan["cks"][i] for i in sel], lr, parallel=parallel,
                pad_to=bucket_size(int(sel.size))
                if (parallel and self.bucketed) else None, mesh=mesh)
            # comms tier: each client's delta base is its DOWNLOAD RSU's
            # model (the tree it trained from); rows=sel keeps EF slots
            # in cohort order, matching the engine's per-row gather.
            # Bucketed padding rows are re-padded from the decoded rows.
            cohort, comms = roundtrip_cohort(cfg, cohort, rsu_models[rsu],
                                             comms, rows=sel)
            group_sel.append(sel)
            group_cohorts.append(cohort)
        # one stacked cohort of all n valid clients (padding dropped),
        # rows in download-group order; row_of maps cohort index -> row
        n = cfg.vehicles_per_round
        full = CohortBatch.concat(group_cohorts)
        order = np.concatenate(group_sel)
        row_of = np.empty(n, np.int64)
        row_of[order] = np.arange(n)

        # Step 3-4: upload groups are device-side gathers out of the
        # stacked cohort — the old path unstacked into n host trees and
        # re-stacked per RSU
        for rsu, sel, w in plan["uploads"]:
            sub = full.take(row_of[sel])
            rsu_models[rsu] = agg.cohort_weighted_sum(sub, w)

        new_tree = state.global_tree
        if plan["synced"]:
            new_tree = agg._weighted_tree_sum(rsu_models, plan["sync_W"])
            rsu_models = [new_tree] * self.n_rsus
        # between syncs global_tree keeps the last merged model; RSU models
        # stay divergent until sync (region_view() merges on demand without
        # paying an n_rsus-model sum every round)
        losses_g, vels, lr_h = _record_fetch(full.losses, velocities, lr)
        losses = losses_g[row_of]                 # back to cohort order
        rec = {"round": state.round, "loss": float(np.mean(losses)),
               "velocities": vels,
               "lr": lr_h, "topology": self.name,
               "rsu_sizes": plan["upload_sizes"],
               # analysis: allow=host-sync-cast -- plan arrays are host numpy
               "n_handovers": int(plan["stale"].sum()),
               "synced": plan["synced"]}
        topo = {"positions": plan["positions"],
                "rsu_models": tuple(rsu_models),
                "blur_sum": plan["blur_sum"],
                "upload_count": plan["upload_count"]}
        return state.replace(global_tree=new_tree, key=plan["key"],
                             host_rng=pack_host_rng(rng),
                             round=state.round + 1, topo=topo,
                             comms=comms), rec

    def region_view(self, state: FLState):
        """Uniform merge of the current per-RSU models — an evaluation
        snapshot between syncs; does not touch the state."""
        return agg.aggregate_fedavg(list(state.topo["rsu_models"]))


TOPOLOGIES = {
    "single": SingleRSU,
    "multi": MultiRSU,
    "handover": HandoverMultiRSU,
}
