"""Synthetic datasets + federated partitioners.

The container is offline, so CIFAR-10 is replaced by a deterministic
10-class synthetic image generator (DESIGN.md deviation #1): each class is
a distinct procedural texture (oriented gratings, blobs, checkers) with
per-sample random phase/position/color — linearly separable enough for a
kNN probe to measure representation quality, hard enough that training
matters.

Partitioners reproduce the paper's Sec. 5.1 splits: IID uniform and
Dirichlet(alpha) Non-IID with a >= `min_per_client` floor (paper: 520
images per vehicle, 95 vehicles).
"""
from __future__ import annotations

import numpy as np

N_CLASSES = 10
IMG = 32


def make_dataset(n_per_class: int = 5000, seed: int = 0, img: int = IMG):
    """Returns (images (N,img,img,3) float32 in [0,1], labels (N,) int32)."""
    rng = np.random.RandomState(seed)
    xs, ys = [], []
    yy, xx = np.meshgrid(np.arange(img), np.arange(img), indexing="ij")
    for c in range(N_CLASSES):
        n = n_per_class
        phase = rng.uniform(0, 2 * np.pi, (n, 1, 1))
        freq = 0.2 + 0.08 * c
        angle = np.pi * c / N_CLASSES
        gx = np.cos(angle) * xx + np.sin(angle) * yy
        base = 0.5 + 0.5 * np.sin(freq * gx[None] + phase)           # (n,img,img)
        # class-specific blob
        cx = rng.uniform(6, img - 6, (n, 1, 1))
        cy = rng.uniform(6, img - 6, (n, 1, 1))
        r2 = (xx[None] - cx) ** 2 + (yy[None] - cy) ** 2
        blob = np.exp(-r2 / (2 * (2.0 + 0.6 * c) ** 2))
        lum = 0.6 * base + 0.4 * blob
        # class-tinted color with per-sample jitter
        hue = np.array([np.cos(2 * np.pi * c / N_CLASSES),
                        np.cos(2 * np.pi * c / N_CLASSES + 2.1),
                        np.cos(2 * np.pi * c / N_CLASSES + 4.2)]) * 0.25 + 0.75
        tint = hue[None, None, None, :] * (1 + rng.uniform(-0.1, 0.1, (n, 1, 1, 3)))
        im = lum[..., None] * tint + rng.normal(0, 0.05, (n, img, img, 3))
        xs.append(np.clip(im, 0, 1).astype(np.float32))
        ys.append(np.full((n,), c, np.int32))
    x = np.concatenate(xs)
    y = np.concatenate(ys)
    perm = rng.permutation(len(x))
    return x[perm], y[perm]


def partition_iid(labels, n_clients: int, seed: int = 0):
    """Uniform IID split; returns list of index arrays."""
    rng = np.random.RandomState(seed)
    idx = rng.permutation(len(labels))
    return np.array_split(idx, n_clients)


def partition_dirichlet(labels, n_clients: int, alpha: float,
                        min_per_client: int = 0, seed: int = 0):
    """Dirichlet(alpha) Non-IID split (paper Fig. 3; alpha=0.1 in Sec. 5.1).

    Re-draws until every client holds >= min_per_client samples, matching
    the paper's "at least 520 images per vehicle" constraint.
    """
    rng = np.random.RandomState(seed)
    n_classes = int(labels.max()) + 1
    for _attempt in range(100):
        client_idx = [[] for _ in range(n_clients)]
        for c in range(n_classes):
            idx_c = np.where(labels == c)[0]
            rng.shuffle(idx_c)
            props = rng.dirichlet([alpha] * n_clients)
            cuts = (np.cumsum(props) * len(idx_c)).astype(int)[:-1]
            for ci, part in enumerate(np.split(idx_c, cuts)):
                client_idx[ci].extend(part.tolist())
        sizes = np.array([len(ix) for ix in client_idx])
        if min_per_client == 0 or sizes.min() >= min_per_client:
            return [np.array(sorted(ix)) for ix in client_idx]
        # top-up small clients from the largest ones (paper guarantees >=520)
        order = np.argsort(sizes)
        donors = list(order[::-1])
        for ci in order:
            while len(client_idx[ci]) < min_per_client:
                d = donors[0]
                if len(client_idx[d]) <= min_per_client:
                    donors.pop(0)
                    continue
                client_idx[ci].append(client_idx[d].pop())
        return [np.array(sorted(ix)) for ix in client_idx]
    raise RuntimeError("dirichlet partition failed")


def category_histogram(labels, parts, n_classes: int = N_CLASSES):
    """Per-client class histogram — reproduces the paper's Fig. 3 data."""
    return np.stack([np.bincount(labels[p], minlength=n_classes) for p in parts])


def token_batch(rng: np.random.RandomState, batch: int, seq: int, vocab: int):
    """Synthetic token stream (Zipf-ish) for LM-objective training paths."""
    z = rng.zipf(1.3, size=(batch, seq))
    return (z % (vocab - 2) + 1).astype(np.int32)
