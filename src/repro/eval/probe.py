"""Representation-quality evaluation — the paper's Top-1 test protocol.

The paper ranks predicted labels by probability and scores Top-1. For an
SSL encoder that protocol needs a probe; we provide both standard ones:

* kNN probe (weighted kNN on L2-normalized features, the usual contrastive
  -learning monitor) — cheap, no extra training, used by benchmarks.
* linear probe (one linear layer trained on frozen features with SGD) —
  closer to the paper's fine-tune-then-classify setting.

Each experiment is averaged over repeats upstream (paper: 3 runs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.resnet import resnet_apply


def encode(tree, images, batch: int = 256, use_projector: bool = False):
    """Frozen-encoder features (pre-projector 512-D by default)."""
    outs = []
    fn = jax.jit(lambda t, x: resnet_apply(t, x, train=False)[:2])
    for i in range(0, len(images), batch):
        z, h, = fn(tree, jnp.asarray(images[i:i + batch]))
        outs.append(np.asarray(z if use_projector else h))
    f = np.concatenate(outs)
    f = f / np.maximum(np.linalg.norm(f, axis=-1, keepdims=True), 1e-8)
    return f


def knn_top1(train_feats, train_labels, test_feats, test_labels,
             k: int = 20, tau: float = 0.1) -> float:
    """Weighted-kNN Top-1 accuracy (Wu et al. protocol)."""
    n_classes = int(train_labels.max()) + 1
    correct = 0
    bs = 512
    for i in range(0, len(test_feats), bs):
        sims = test_feats[i:i + bs] @ train_feats.T                # (b, N)
        topk = np.argpartition(-sims, k, axis=1)[:, :k]
        w = np.exp(np.take_along_axis(sims, topk, axis=1) / tau)
        votes = np.zeros((len(topk), n_classes))
        for c in range(n_classes):
            votes[:, c] = (w * (train_labels[topk] == c)).sum(axis=1)
        pred = votes.argmax(axis=1)
        correct += (pred == test_labels[i:i + bs]).sum()
    return float(correct) / len(test_feats)


def linear_probe_top1(train_feats, train_labels, test_feats, test_labels,
                      epochs: int = 20, lr: float = 0.5, seed: int = 0) -> float:
    """Train a linear classifier on frozen features; return test Top-1."""
    rng = np.random.RandomState(seed)
    n_classes = int(train_labels.max()) + 1
    d = train_feats.shape[1]
    W = jnp.zeros((d, n_classes), jnp.float32)
    b = jnp.zeros((n_classes,), jnp.float32)
    x = jnp.asarray(train_feats)
    y = jnp.asarray(train_labels)

    @jax.jit
    def step(W, b, xb, yb, lr):
        def loss_fn(Wb):
            W_, b_ = Wb
            logits = xb @ W_ + b_
            return -jax.nn.log_softmax(logits)[jnp.arange(len(yb)), yb].mean()
        g = jax.grad(loss_fn)((W, b))
        return W - lr * g[0], b - lr * g[1]

    bs = 512
    for e in range(epochs):
        perm = rng.permutation(len(x))
        for i in range(0, len(x), bs):
            idx = perm[i:i + bs]
            W, b = step(W, b, x[idx], y[idx], lr * (0.5 ** (e // 8)))
    logits = np.asarray(jnp.asarray(test_feats) @ W + b)
    return float((logits.argmax(1) == test_labels).mean())
