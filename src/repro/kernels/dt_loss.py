"""Fused dual-temperature loss — Pallas TPU kernel.

The paper's inner-loop compute is an (M, M) similarity matrix followed by
TWO softmaxes (tau_alpha, tau_beta) and a weighted NLL. Unfused, XLA
materializes the logits twice ((M,M) f32 each) plus the probability
tensors — 3-4 HBM round trips of M^2 data. This kernel streams K-blocks
through VMEM once, maintaining online logsumexp accumulators for BOTH
temperatures simultaneously, and never writes an (M, M) intermediate.

Layout: grid over (M/BM) anchor-row blocks; inner fori_loop walks key
blocks of BN columns. Blocks are (BM, BN) = (128, 128) — MXU-aligned.
q/k rows are zero-padded to multiples of 128 by the ops.py wrapper
(padded rows produce sim 0 everywhere; the wrapper masks them out of the
mean).

TPU mapping notes (HARDWARE ADAPTATION): the (BM, D) @ (D, BN) tile hits
the MXU; the two exp/max/sum accumulator sets live in VREGs; f32
accumulation throughout (inputs may be bf16).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30
BM = 128
BN = 128


def _dt_fwd_kernel(q_ref, k_ref, o_loss, o_lsea, o_lseb, o_pos, *,
                   tau_alpha: float, tau_beta: float, n_valid: int):
    """One grid step: BM anchors vs all keys (looped in BN blocks)."""
    row_block = pl.program_id(0)
    q = q_ref[...].astype(jnp.float32)                       # (BM, D)
    M = k_ref.shape[0]
    n_kb = M // BN

    row_ids = row_block * BM + jax.lax.broadcasted_iota(jnp.int32, (BM, 1), 0)

    def body(j, carry):
        m_a, l_a, m_b, l_b, pos = carry
        k = pl.load(k_ref, (pl.dslice(j * BN, BN), slice(None))).astype(jnp.float32)
        sim = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (BM, BN)
        col_ids = j * BN + jax.lax.broadcasted_iota(jnp.int32, (1, BN), 1)
        valid = col_ids < n_valid                            # mask padded keys
        sim = jnp.where(valid, sim, NEG)
        # capture diagonal positives
        is_diag = row_ids == col_ids
        pos = pos + jnp.sum(jnp.where(is_diag, sim, 0.0), axis=1)
        # online logsumexp at both temperatures
        sa = sim / tau_alpha
        sb = sim / tau_beta
        m_a2 = jnp.maximum(m_a, sa.max(axis=1))
        l_a = l_a * jnp.exp(m_a - m_a2) + jnp.sum(
            jnp.where(sa <= NEG / 2, 0.0, jnp.exp(sa - m_a2[:, None])), axis=1)
        m_b2 = jnp.maximum(m_b, sb.max(axis=1))
        l_b = l_b * jnp.exp(m_b - m_b2) + jnp.sum(
            jnp.where(sb <= NEG / 2, 0.0, jnp.exp(sb - m_b2[:, None])), axis=1)
        return m_a2, l_a, m_b2, l_b, pos

    init = (jnp.full((BM,), NEG, jnp.float32), jnp.zeros((BM,), jnp.float32),
            jnp.full((BM,), NEG, jnp.float32), jnp.zeros((BM,), jnp.float32),
            jnp.zeros((BM,), jnp.float32))
    m_a, l_a, m_b, l_b, pos = jax.lax.fori_loop(0, n_kb, body, init)

    lse_a = m_a + jnp.log(jnp.maximum(l_a, 1e-30))
    lse_b = m_b + jnp.log(jnp.maximum(l_b, 1e-30))
    log_pa = pos / tau_alpha - lse_a
    w_a = 1.0 - jnp.exp(log_pa)
    w_b = 1.0 - jnp.exp(pos / tau_beta - lse_b)
    weight = w_b / jnp.maximum(w_a, 1e-8)
    o_loss[...] = -weight * log_pa
    o_lsea[...] = lse_a
    o_lseb[...] = lse_b
    o_pos[...] = pos


def dt_loss_fwd_pallas(q, k, tau_alpha: float, tau_beta: float,
                       n_valid: int, *, interpret: bool = True):
    """q, k: (Mp, D) with Mp % 128 == 0 (wrapper pads). Returns
    (loss_vec, lse_a, lse_b, pos) of shape (Mp,)."""
    Mp, D = q.shape
    assert Mp % BM == 0 and Mp % BN == 0, (Mp, BM)
    grid = (Mp // BM,)
    kernel = functools.partial(_dt_fwd_kernel, tau_alpha=tau_alpha,
                               tau_beta=tau_beta, n_valid=n_valid)
    out_shape = [jax.ShapeDtypeStruct((Mp,), jnp.float32)] * 4
    vec_spec = pl.BlockSpec((BM,), lambda i: (i,))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BM, D), lambda i: (i, 0)),   # q rows for this block
            pl.BlockSpec((Mp, D), lambda i: (0, 0)),   # full k (streamed via dslice)
        ],
        out_specs=[vec_spec] * 4,
        out_shape=out_shape,
        interpret=interpret,
    )(q, k)
