"""Public jit'd wrappers around the Pallas kernels.

* ``dt_loss(q, k, ...)`` — differentiable (custom_vjp: Pallas forward, the
  analytic jnp backward recomputes the similarity tile-free, flash-style).
* ``wagg_stacked(stacked_tree, w, mask)`` — blur-weighted aggregation of
  a stacked cohort pytree (leading client axis) through the fused kernel
  (ravel rows -> kernel -> unravel); ``mask`` zeroes padding rows of a
  bucketed `CohortBatch` inside the kernel.
* ``wagg_tree(trees, w)`` — same for a legacy list of client pytrees
  (stack once, then the fused pass).
* ``rwkv6(r, k, v, logw, u)`` — chunked recurrence (forward).

On this CPU container kernels execute in interpret mode; on TPU set
``interpret=False`` (the default flips on the backend).
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.dt_loss import BM, dt_loss_fwd_pallas
from repro.kernels.qdelta import BQ, BT, q8_decode_pallas, q8_encode_pallas
from repro.kernels.rwkv6 import CHUNK, rwkv6_pallas
from repro.kernels.wagg import BP, wagg_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, multiple):
    M = x.shape[0]
    pad = (-M) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, M


# --------------------------------------------------------------------------
# dt loss
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def dt_loss(q, k, tau_alpha: float = 0.1, tau_beta: float = 1.0,
            interpret: bool | None = None):
    """Mean dual-temperature loss over in-batch similarities (fused)."""
    loss, _, _, _ = _dt_fwd(q, k, tau_alpha, tau_beta, interpret)
    return loss


def _dt_fwd(q, k, tau_alpha, tau_beta, interpret):
    interpret = _default_interpret() if interpret is None else interpret
    M = q.shape[0]
    qp, _ = _pad_rows(q, BM)
    kp, _ = _pad_rows(k, BM)
    lvec, lse_a, lse_b, pos = dt_loss_fwd_pallas(
        qp, kp, tau_alpha, tau_beta, n_valid=M, interpret=interpret)
    loss = lvec[:M].mean()
    return loss, lse_a[:M], lse_b[:M], pos[:M]


def _dt_fwd_vjp(q, k, tau_alpha, tau_beta, interpret):
    loss, lse_a, lse_b, pos = _dt_fwd(q, k, tau_alpha, tau_beta, interpret)
    return loss, (q, k, lse_a, pos)


def _dt_bwd(tau_alpha, tau_beta, interpret, res, g):
    """d/dq, d/dk of mean_i [ -w_i * (pos_i/ta - lse_a_i) ] with w_i
    treated as constant (stop_gradient in Eq. 6)."""
    q, k, lse_a, pos = res
    M = q.shape[0]
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    sim = qf @ kf.T
    log_pa = pos / tau_alpha - lse_a
    w_a = 1.0 - jnp.exp(log_pa)
    w_b = 1.0 - jnp.exp(pos / tau_beta -
                        jax.nn.logsumexp(sim / tau_beta, axis=-1))
    weight = w_b / jnp.maximum(w_a, 1e-8)
    p_a = jnp.exp(sim / tau_alpha - lse_a[:, None])          # (M, M)
    # dL_i/dsim_ij = w_i/ta * (p_a_ij - delta_ij); mean over i adds 1/M
    coef = (g * weight / (tau_alpha * M))[:, None]
    dsim = coef * (p_a - jnp.eye(M, dtype=jnp.float32))
    dq = (dsim @ kf).astype(q.dtype)
    dk = (dsim.T @ qf).astype(k.dtype)
    return dq, dk


dt_loss.defvjp(_dt_fwd_vjp, _dt_bwd)


# --------------------------------------------------------------------------
# weighted aggregation
# --------------------------------------------------------------------------

def wagg_flat(stacked, w, interpret: bool | None = None, mask=None):
    """stacked (N, P) x w (N,) -> (P,) f32 via the fused kernel (pads P).

    `mask` (N,) optionally zeroes rows inside the kernel (padding rows of
    a bucketed cohort). On TPU the kernel tiles P into BP-sized VMEM
    blocks. In interpret mode the per-grid-step overhead dominates (a
    ResNet-18 tree is ~5500 BP blocks), so the whole padded axis becomes
    one block — same kernel, grid of 1.
    """
    interpret = _default_interpret() if interpret is None else interpret
    N, P = stacked.shape
    pad = (-P) % BP
    if pad:
        # analysis: allow=retrace-fresh-array -- device-side zero pad
        # to the kernel block size; width follows P, nothing to hoist
        stacked = jnp.concatenate(
            [stacked, jnp.zeros((N, pad), stacked.dtype)], axis=1)
    block = stacked.shape[1] if interpret else BP
    out = wagg_pallas(stacked, w, mask, interpret=interpret, block=block)
    return out[:P]


def _unravel_like(out, tree):
    """(P,) f32 -> the structure/dtypes of `tree` (inverse of raveling)."""
    leaves, treedef = jax.tree.flatten(tree)
    new_leaves, off = [], 0
    for l in leaves:
        n = l.size
        new_leaves.append(out[off:off + n].reshape(l.shape).astype(l.dtype))
        off += n
    return jax.tree.unflatten(treedef, new_leaves)


def wagg_stacked(stacked_tree, w, mask=None, interpret: bool | None = None):
    """Weighted sum over the leading cohort axis of a STACKED pytree.

    Every leaf of `stacked_tree` is (N, ...); the leaves are raveled to
    one (N, P) matrix (a per-row view of the same memory layout
    `wagg_tree` builds by stacking N flat trees) and reduced in one fused
    pass — the `CohortBatch` path hands the kernel its stacked tensor
    without ever unstacking into per-client trees.
    """
    leaves = jax.tree.leaves(stacked_tree)
    N = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(N, -1).astype(jnp.float32) for l in leaves], axis=1)
    # analysis: allow=retrace-fresh-array -- f32 normalization at the
    # kernel boundary (no-op for device weights)
    w = jnp.asarray(w, jnp.float32)
    out = wagg_flat(flat, w, interpret, mask=mask)
    return _unravel_like(out, jax.tree.map(lambda x: x[0], stacked_tree))


def wagg_tree(trees: Sequence, w, interpret: bool | None = None):
    """Weighted sum of a LIST of client pytrees (legacy boundary): stacks
    once, then runs the same fused pass as `wagg_stacked`."""
    flats = []
    for t in trees:
        leaves = jax.tree.leaves(t)
        flats.append(jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                                      for l in leaves]))
    stacked = jnp.stack(flats)
    # analysis: allow=retrace-fresh-array -- legacy list-API boundary
    w = jnp.asarray(w, jnp.float32)
    out = wagg_flat(stacked, w, interpret)
    return _unravel_like(out, trees[0])


# --------------------------------------------------------------------------
# blockwise-int8 delta codec (comms tier)
# --------------------------------------------------------------------------

def _pad_cols(x, multiple):
    P = x.shape[1]
    pad = (-P) % multiple
    if pad:
        # analysis: allow=retrace-fresh-array -- device-side zero pad to
        # the kernel block size; width follows P, nothing to hoist
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:1] + (pad,), x.dtype)], axis=1)
    return x, P


def q8_encode_flat(flat, ef, backend: str = "auto"):
    """Blockwise-int8 encode of an (N, P) f32 delta matrix, P % BQ == 0.

    backend: "auto" (fused kernel on TPU, jnp reference elsewhere),
    "fused", "interpret" (Pallas in interpret mode — the CPU parity
    path), or "ref". Returns (codes (N, P) int8, scales (N, P/BQ) f32,
    new_ef (N, P) f32) — semantics defined by `ref.q8_encode_ref`.
    """
    if backend == "auto":
        backend = "fused" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.q8_encode_ref(flat, ef, block=BQ)
    interpret = backend == "interpret"
    # interpret mode wants a grid of 1 (same policy as wagg_flat); the
    # compiled TPU kernel tiles P into BT-sized VMEM blocks
    padded, P = _pad_cols(flat, BQ if interpret else BT)
    ef_p, _ = _pad_cols(ef, BQ if interpret else BT)
    block = padded.shape[1] if interpret else BT
    codes, scales, new_ef = q8_encode_pallas(padded, ef_p,
                                             interpret=interpret,
                                             block=block)
    return codes[:, :P], scales[:, :P // BQ], new_ef[:, :P]


def q8_decode_flat(codes, scales, backend: str = "auto"):
    """Dequantize (N, P) int8 codes with (N, P/BQ) scales -> (N, P) f32
    (semantics: `ref.q8_decode_ref`; backends as `q8_encode_flat`)."""
    if backend == "auto":
        backend = "fused" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.q8_decode_ref(codes, scales, block=BQ)
    interpret = backend == "interpret"
    padded, P = _pad_cols(codes, BQ if interpret else BT)
    sc_p, _ = _pad_cols(scales, 1 if interpret else BT // BQ)
    block = padded.shape[1] if interpret else BT
    out = q8_decode_pallas(padded, sc_p, interpret=interpret, block=block)
    return out[:, :P]


# --------------------------------------------------------------------------
# rwkv6
# --------------------------------------------------------------------------

def rwkv6(r, k, v, logw, u, interpret: bool | None = None):
    """Chunked RWKV6 recurrence; pads S to the chunk size."""
    interpret = _default_interpret() if interpret is None else interpret
    BH, S, D = r.shape
    pad = (-S) % CHUNK
    if pad:
        z = jnp.zeros((BH, pad, D), r.dtype)
        r, k, v = (jnp.concatenate([t, z], 1) for t in (r, k, v))
        logw = jnp.concatenate([logw, jnp.full((BH, pad, D), -1e-4, logw.dtype)], 1)
    o, state = rwkv6_pallas(r, k, v, logw, u, interpret=interpret)
    return o[:, :S], state
