"""Fused blockwise-int8 delta quantize/dequantize — Pallas TPU kernels.

The comms tier (src/repro/comms/) uploads per-client model deltas
Δ_n = θ_n − θ quantized to int8 with one f32 scale per 256-parameter
block (`BQ`) and an error-feedback residual folded in before
quantization. Done as separate jnp ops the (N, P) delta matrix crosses
HBM five times (add residual, absmax, scale, round, subtract); these
kernels fuse the whole codec step into one pass per direction:

  encode: (delta, ef) -> (codes int8, scales f32, new_ef f32)
  decode: (codes, scales) -> delta_hat f32

Grid: (P / block,) with `block` a multiple of BQ — each grid step holds
an (N, block) VMEM tile, reshapes it to (N, block/BQ, BQ) VREG-resident
sub-blocks and computes the per-block scales with a lane reduction. On
TPU the tile defaults to BT; in interpret mode callers pass one
whole-axis block (per-grid-step overhead dominates, same policy as
`wagg`). The op sequence matches kernels/ref.py `q8_encode_ref` /
`q8_decode_ref` exactly, so interpret-mode parity is bitwise
(tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 256       # quantization block: parameters sharing one f32 scale
BT = 2048      # TPU tile: BT // BQ scales per grid step


def _q8_encode_kernel(x_ref, e_ref, c_ref, s_ref, r_ref):
    N, T = x_ref.shape
    y = (x_ref[...] + e_ref[...]).reshape(N, T // BQ, BQ)
    absmax = jnp.max(jnp.abs(y), axis=-1)
    scales = absmax * jnp.float32(1.0 / 127.0)
    inv = jnp.where(scales > 0.0, 1.0 / scales, 0.0)
    codes = jnp.clip(jnp.round(y * inv[..., None]), -127.0, 127.0)
    codes = codes.astype(jnp.int8)
    deq = codes.astype(jnp.float32) * scales[..., None]
    c_ref[...] = codes.reshape(N, T)
    s_ref[...] = scales
    r_ref[...] = (y - deq).reshape(N, T)


def _q8_decode_kernel(c_ref, s_ref, o_ref):
    N, T = c_ref.shape
    deq = (c_ref[...].reshape(N, T // BQ, BQ).astype(jnp.float32)
           * s_ref[...][..., None])
    o_ref[...] = deq.reshape(N, T)


def q8_encode_pallas(flat, ef, *, interpret: bool = True,
                     block: int | None = None):
    """flat, ef: (N, P) f32 with P % block == 0 and block % BQ == 0.

    Returns (codes (N, P) int8, scales (N, P/BQ) f32, new_ef (N, P)
    f32). The wrapper in kernels/ops.py pads P and picks the block.
    """
    N, P = flat.shape
    block = BT if block is None else block
    assert P % block == 0 and block % BQ == 0, (P, block)
    return pl.pallas_call(
        _q8_encode_kernel,
        grid=(P // block,),
        in_specs=[pl.BlockSpec((N, block), lambda i: (0, i)),
                  pl.BlockSpec((N, block), lambda i: (0, i))],
        out_specs=(pl.BlockSpec((N, block), lambda i: (0, i)),
                   pl.BlockSpec((N, block // BQ), lambda i: (0, i)),
                   pl.BlockSpec((N, block), lambda i: (0, i))),
        out_shape=(jax.ShapeDtypeStruct((N, P), jnp.int8),
                   jax.ShapeDtypeStruct((N, P // BQ), jnp.float32),
                   jax.ShapeDtypeStruct((N, P), jnp.float32)),
        interpret=interpret,
    )(flat, ef)


def q8_decode_pallas(codes, scales, *, interpret: bool = True,
                     block: int | None = None):
    """codes: (N, P) int8, scales: (N, P/BQ) f32 -> (N, P) f32."""
    N, P = codes.shape
    block = BT if block is None else block
    assert P % block == 0 and block % BQ == 0, (P, block)
    return pl.pallas_call(
        _q8_decode_kernel,
        grid=(P // block,),
        in_specs=[pl.BlockSpec((N, block), lambda i: (0, i)),
                  pl.BlockSpec((N, block // BQ), lambda i: (0, i))],
        out_specs=pl.BlockSpec((N, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((N, P), jnp.float32),
        interpret=interpret,
    )(codes, scales)
