"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are the semantic definitions; kernels/*.py must match them for all
shapes/dtypes the tests sweep. They are also the CPU fallback path used
when ``use_pallas=False``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# dual-temperature loss (in-batch form)
# --------------------------------------------------------------------------

def dt_loss_fwd_ref(q, k, tau_alpha: float, tau_beta: float):
    """Returns (loss_vec (B,), lse_a (B,), lse_b (B,), pos (B,)).

    loss_i = -sg[(1-softmax_b(pos))/(1-softmax_a(pos))] * log softmax_a(pos)
    over the in-batch similarity row sim_i = q_i @ k^T (positive = diag).
    """
    sim = (q.astype(jnp.float32) @ k.astype(jnp.float32).T)
    pos = jnp.diagonal(sim)
    lse_a = jax.nn.logsumexp(sim / tau_alpha, axis=-1)
    lse_b = jax.nn.logsumexp(sim / tau_beta, axis=-1)
    log_pa = pos / tau_alpha - lse_a
    w_a = 1.0 - jnp.exp(log_pa)
    w_b = 1.0 - jnp.exp(pos / tau_beta - lse_b)
    weight = w_b / jnp.maximum(w_a, 1e-8)
    loss = -weight * log_pa
    return loss, lse_a, lse_b, pos


def dt_loss_ref(q, k, tau_alpha: float = 0.1, tau_beta: float = 1.0):
    return dt_loss_fwd_ref(q, k, tau_alpha, tau_beta)[0].mean()


# --------------------------------------------------------------------------
# weighted aggregation
# --------------------------------------------------------------------------

def wagg_ref(stacked, w):
    """stacked: (N, P) client-stacked flat params; w: (N,) -> (P,)."""
    return jnp.tensordot(w.astype(jnp.float32),
                         stacked.astype(jnp.float32), axes=1)


# --------------------------------------------------------------------------
# rwkv6 chunked recurrence (single head-batch layout)
# --------------------------------------------------------------------------

def rwkv6_ref(r, k, v, logw, u, state0=None):
    """Sequential oracle. r,k,v,logw: (BH, S, D); u: (D,) or (BH, D).

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ; o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    Returns (o (BH,S,D), state (BH,D,D)).
    """
    BH, S, D = r.shape
    if state0 is None:
        state0 = jnp.zeros((BH, D, D), jnp.float32)
    u = jnp.broadcast_to(u, (BH, D)) if u.ndim == 1 else u

    def step(S_, xs):
        rt, kt, vt, lwt = xs
        kv = kt[:, :, None] * vt[:, None, :]
        o = jnp.einsum("bd,bde->be", rt, S_ + u[:, :, None] * kv)
        S_ = S_ * jnp.exp(lwt)[:, :, None] + kv
        return S_, o

    xs = tuple(t.astype(jnp.float32).transpose(1, 0, 2) for t in (r, k, v, logw))
    state, o = jax.lax.scan(step, state0, xs)
    return o.transpose(1, 0, 2), state
