"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

These are the semantic definitions; kernels/*.py must match them for all
shapes/dtypes the tests sweep. They are also the CPU fallback path used
when ``use_pallas=False``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# dual-temperature loss (in-batch form)
# --------------------------------------------------------------------------

def dt_loss_fwd_ref(q, k, tau_alpha: float, tau_beta: float):
    """Returns (loss_vec (B,), lse_a (B,), lse_b (B,), pos (B,)).

    loss_i = -sg[(1-softmax_b(pos))/(1-softmax_a(pos))] * log softmax_a(pos)
    over the in-batch similarity row sim_i = q_i @ k^T (positive = diag).
    """
    sim = (q.astype(jnp.float32) @ k.astype(jnp.float32).T)
    pos = jnp.diagonal(sim)
    lse_a = jax.nn.logsumexp(sim / tau_alpha, axis=-1)
    lse_b = jax.nn.logsumexp(sim / tau_beta, axis=-1)
    log_pa = pos / tau_alpha - lse_a
    w_a = 1.0 - jnp.exp(log_pa)
    w_b = 1.0 - jnp.exp(pos / tau_beta - lse_b)
    weight = w_b / jnp.maximum(w_a, 1e-8)
    loss = -weight * log_pa
    return loss, lse_a, lse_b, pos


def dt_loss_ref(q, k, tau_alpha: float = 0.1, tau_beta: float = 1.0):
    return dt_loss_fwd_ref(q, k, tau_alpha, tau_beta)[0].mean()


# --------------------------------------------------------------------------
# weighted aggregation
# --------------------------------------------------------------------------

def wagg_ref(stacked, w):
    """stacked: (N, P) client-stacked flat params; w: (N,) -> (P,)."""
    return jnp.tensordot(w.astype(jnp.float32),
                         stacked.astype(jnp.float32), axes=1)


# --------------------------------------------------------------------------
# blockwise-int8 delta quantization (comms codec)
# --------------------------------------------------------------------------

def q8_encode_ref(flat, ef, block: int = 256):
    """Blockwise symmetric int8 quantization with error feedback.

    flat, ef: (N, P) f32 with P % block == 0. Each length-`block` slice
    of a row gets its own scale max|y| * (1/127) (y = flat + ef, the
    residual folded in BEFORE quantization; the constant reciprocal
    multiply — not a true division — keeps the Pallas kernel bitwise-
    identical, since backends lower x/127.0 differently); codes are
    round-half-even (jnp.round) in [-127, 127]; all-zero blocks take
    scale 0 and decode to exact zeros. Returns (codes int8 (N, P),
    scales f32 (N, P/block), new_ef f32 (N, P)) where
    new_ef = y - dequant(codes) is the residual the NEXT round folds
    back in.
    """
    N, P = flat.shape
    y = (flat + ef).reshape(N, P // block, block)
    absmax = jnp.max(jnp.abs(y), axis=-1)
    scales = absmax * jnp.float32(1.0 / 127.0)
    inv = jnp.where(scales > 0.0, 1.0 / scales, 0.0)
    codes = jnp.clip(jnp.round(y * inv[..., None]), -127.0, 127.0)
    codes = codes.astype(jnp.int8)
    deq = codes.astype(jnp.float32) * scales[..., None]
    new_ef = (y - deq).reshape(N, P)
    return codes.reshape(N, P), scales, new_ef


def q8_decode_ref(codes, scales, block: int = 256):
    """Inverse of `q8_encode_ref` up to the quantization error: (N, P)
    int8 codes x (N, P/block) f32 scales -> (N, P) f32."""
    N, P = codes.shape
    deq = (codes.reshape(N, P // block, block).astype(jnp.float32)
           * scales[..., None])
    return deq.reshape(N, P)


# --------------------------------------------------------------------------
# rwkv6 chunked recurrence (single head-batch layout)
# --------------------------------------------------------------------------

def rwkv6_ref(r, k, v, logw, u, state0=None):
    """Sequential oracle. r,k,v,logw: (BH, S, D); u: (D,) or (BH, D).

    S_t = diag(w_t) S_{t-1} + k_t v_t^T ; o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
    Returns (o (BH,S,D), state (BH,D,D)).
    """
    BH, S, D = r.shape
    if state0 is None:
        state0 = jnp.zeros((BH, D, D), jnp.float32)
    u = jnp.broadcast_to(u, (BH, D)) if u.ndim == 1 else u

    def step(S_, xs):
        rt, kt, vt, lwt = xs
        kv = kt[:, :, None] * vt[:, None, :]
        o = jnp.einsum("bd,bde->be", rt, S_ + u[:, :, None] * kv)
        S_ = S_ * jnp.exp(lwt)[:, :, None] + kv
        return S_, o

    xs = tuple(t.astype(jnp.float32).transpose(1, 0, 2) for t in (r, k, v, logw))
    state, o = jax.lax.scan(step, state0, xs)
    return o.transpose(1, 0, 2), state
