"""RWKV6 (Finch) chunked recurrence — Pallas TPU kernel.

The attention-free arch's hot loop: per (batch, head),
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ;  o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
with data-dependent per-channel decay w_t. A token-sequential scan wastes
the MXU; the chunked form turns it into dense (C,D)x(D,D)/(C,C)x(C,D)
matmuls with the decay folded in AFTER the cum-difference (exponent <= 0,
so no rescaling pass — see models/layers.rwkv_tmix_chunked).

TPU mapping: grid is (BH, S/C) with the S/C dimension marked
sequential-innermost; the running state lives in a VMEM scratch buffer
(D, D) f32 that persists across chunk steps of the same (batch*head) row —
the standard linear-attention state-carrying pattern. Each chunk step is
a handful of MXU ops on (C, D) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

CHUNK = 16


def _rwkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_out,
                  state_scr, *, n_chunks: int):
    step = pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0, 0].astype(jnp.float32)            # (C, D)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)            # (D,) — per (batch*head) row
    S0 = state_scr[...]                         # (D, D)

    C, D = r.shape
    cum = jnp.cumsum(lw, axis=0)                # (C, D)
    cum_prev = cum - lw
    # carry-in term
    a = r * jnp.exp(cum_prev)
    o = jax.lax.dot_general(a, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)      # (C, D)
    # intra-chunk: scores_ij = sum_d r_id k_jd exp(cum_prev_i - cum_j)_d, j<i
    dec = jnp.exp(cum_prev[:, None, :] - cum[None, :, :])            # (C, C, D)
    tri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    scores = jnp.sum(r[:, None, :] * k[None, :, :] *
                     jnp.where(tri[:, :, None], dec, 0.0), axis=-1)  # (C, C)
    o = o + jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # bonus (current token)
    bonus = jnp.sum(r * u[None, :] * k, axis=-1)                     # (C,)
    o = o + bonus[:, None] * v
    o_ref[0, 0] = o.astype(o_ref.dtype)

    # state update
    total = cum[-1]                                                  # (D,)
    kdec = k * jnp.exp(total[None, :] - cum)                         # (C, D)
    S_new = S0 * jnp.exp(total)[:, None] + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state_scr[...] = S_new

    @pl.when(step == n_chunks - 1)
    def _emit():
        state_out[0] = S_new


def rwkv6_pallas(r, k, v, logw, u, *, interpret: bool = True):
    """r,k,v,logw: (BH, S, D) with S % CHUNK == 0; u: (BH, D) or (D,)
    (per-head bonus; a (D,) u is broadcast to all rows).

    Returns (o (BH,S,D) f32, state (BH,D,D) f32). Matches kernels/ref.py
    rwkv6_ref with zero initial state.
    """
    BH, S, D = r.shape
    if u.ndim == 1:
        u = jnp.broadcast_to(u, (BH, D))
    assert S % CHUNK == 0, (S, CHUNK)
    n_chunks = S // CHUNK
    rc = r.reshape(BH, n_chunks, CHUNK, D)
    kc = k.reshape(BH, n_chunks, CHUNK, D)
    vc = v.reshape(BH, n_chunks, CHUNK, D)
    lwc = logw.reshape(BH, n_chunks, CHUNK, D)

    kernel = functools.partial(_rwkv6_kernel, n_chunks=n_chunks)
    o, state = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, CHUNK, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, CHUNK, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, CHUNK, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, 1, CHUNK, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, D), lambda b, s: (b, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, CHUNK, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, D, D), lambda b, s: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, n_chunks, CHUNK, D), jnp.float32),
            jax.ShapeDtypeStruct((BH, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(rc, kc, vc, lwc, u)
    return o.reshape(BH, S, D), state
