"""Fused blur-weighted aggregation — Pallas TPU kernel.

Eq. (11) at the RSU is sum_n w_n * theta_n over N stacked client models.
Done naively (N scale-then-add tree ops) the parameter payload crosses HBM
N times plus N-1 more for the partial sums. This kernel tiles the flat
parameter axis into VMEM blocks and reduces all N clients inside one pass:
exactly P reads + P/N writes of traffic, the memory-bound optimum.

Grid: (P / block,). Block: (N, block) client-major so the N-reduction is a
VREG-resident dot with the (N,) weight vector. On TPU the block defaults
to BP (VMEM-sized); in interpret mode callers may pass a much larger block
— there is no VMEM to respect and interpret overhead is per grid *step*,
so a multi-million-parameter model wants a grid of ~1, not ~5000.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BP = 2048


def _wagg_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (N, BP)
    w = w_ref[...].astype(jnp.float32)          # (N,)
    o_ref[...] = jax.lax.dot_general(
        w[None, :], x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]


def _wagg_masked_kernel(x_ref, w_ref, m_ref, o_ref):
    """Masked variant: rows with mask 0 contribute exactly +0.0.

    The mask multiply happens inside the kernel (VREG-resident), so a
    padded `CohortBatch` feeds its stacked tensor straight in — no
    host-side compaction, and `w*1.0 == w` / `w*0.0 == 0.0` keep the
    result bit-identical to an unpadded call on the valid prefix.
    """
    x = x_ref[...].astype(jnp.float32)          # (N, BP)
    w = (w_ref[...] * m_ref[...]).astype(jnp.float32)   # (N,)
    o_ref[...] = jax.lax.dot_general(
        w[None, :], x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[0]


def wagg_pallas(stacked, w, mask=None, *, interpret: bool = True,
                block: int | None = None):
    """stacked: (N, P) with P % block == 0 (wrapper pads); w: (N,) -> (P,).

    `mask` (N,) optionally zeroes rows inside the kernel (padding rows of
    a bucketed cohort). block defaults to BP (the VMEM-sized tile).
    Interpret-mode callers should pass a large block (see module
    docstring); the wrapper in kernels/ops.py does this automatically.
    """
    N, P = stacked.shape
    block = BP if block is None else block
    assert P % block == 0
    in_specs = [
        pl.BlockSpec((N, block), lambda i: (0, i)),
        pl.BlockSpec((N,), lambda i: (0,)),
    ]
    operands = [stacked, w]
    kernel = _wagg_kernel
    if mask is not None:
        in_specs.append(pl.BlockSpec((N,), lambda i: (0,)))
        operands.append(mask)
        kernel = _wagg_masked_kernel
    return pl.pallas_call(
        kernel,
        grid=(P // block,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((P,), jnp.float32),
        interpret=interpret,
    )(*operands)
