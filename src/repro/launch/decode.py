"""Transformer decode driver for the generic launch harness — NOT the
FL serving tier.

Scope: this drives the `repro.models.transformer` stack (prefill + KV
-cache decode) over the production mesh — on TPU with sharded
params/cache, on CPU via ``--reduced`` end-to-end or, without it, by
lowering+compiling the decode steps for the assigned shape (the same
artifacts the dry-run checks). It exercises the launch/mesh/steps
plumbing and nothing about federated rounds.

(This file used to live at launch/serve.py; that name now belongs to
the real FL serving driver — RSU model distribution over the
`repro.serve` tier.)

  PYTHONPATH=src python -m repro.launch.decode --arch qwen2-0.5b --reduced
  PYTHONPATH=src python -m repro.launch.decode --arch deepseek-67b \
      --shape decode_32k            # lower+compile only
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import INPUT_SHAPES, InputShape, get_config
from repro.launch import steps as st
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--multi-pod", action="store_true")
    a = ap.parse_args()

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        B, S = 2, 32
        shape = InputShape("cpu", S + a.tokens, B, "decode")
    else:
        mesh = make_production_mesh(multi_pod=a.multi_pod)
        shape = INPUT_SHAPES[a.shape]

    decode = st.make_decode_step(cfg, shape, mesh)

    if not a.reduced:
        specs = st.input_specs(cfg, shape, mesh)
        p_sds, _ = st.params_specs(cfg, mesh)
        with compat.set_mesh(mesh):
            compiled = jax.jit(decode, donate_argnums=(1,)).lower(
                p_sds, specs).compile()
        print(compiled.memory_analysis())
        return

    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, S = 2, 32
    prefill = st.make_prefill_step(cfg, InputShape("p", S + a.tokens, B,
                                                   "prefill"), mesh,
                                   param_dtype=jnp.float32)
    toks = jax.random.randint(key, (B, S), 1, cfg.vocab_size)
    with compat.set_mesh(mesh):
        last, cache = jax.jit(prefill)(params, {"tokens": toks})
        tok = jnp.argmax(last[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        jdecode = jax.jit(decode)
        t0 = time.time()
        for i in range(a.tokens):
            logits, cache = jdecode(params, {
                "tokens": tok,
                "positions": jnp.full((B,), S + i, jnp.int32),
                "cache": cache})
            tok = jnp.argmax(logits[:, :cfg.vocab_size], -1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"{cfg.name}: {a.tokens} decode steps x {B} seqs "
          f"in {dt*1e3:.0f} ms ({a.tokens*B/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
