"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

Proves the distribution config is coherent without hardware: 512 host
placeholder devices form the production meshes; jit(...).lower(...specs)
.compile() must succeed for all 10 architectures x 4 input shapes on both
the 16x16 single-pod and 2x16x16 multi-pod mesh. Records
memory_analysis() / cost_analysis() plus the HLO collective byte counts
(for EXPERIMENTS.md §Roofline) into benchmarks/results/dryrun.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch a,b] [--shape s,..]
      [--mesh single,multi] [--force] [--objective lm]
"""
# The VERY FIRST lines — before any other import, jax locks the device
# count on first init:
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.launch import steps as st
from repro.launch.mesh import make_production_mesh

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "results")

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
               "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8,
               "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "s64": 8}

_COLL_LINE = re.compile(
    r"=\s*(\(?[a-z0-9_\[\],{}\s()]+?\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE = re.compile(r"([a-z0-9_]+)\[([0-9,]*)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op in the HLO.

    Counts the *result* shapes on the LHS type annotation of each
    collective instruction; '-done' ops are skipped so async pairs are not
    double-counted.
    """
    out: dict = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = _COLL_LINE.search(s)
        if not m or "-done(" in s:
            continue
        kind = m.group(2)
        nbytes = 0
        for dt, dims in _SHAPE.findall(m.group(1)):
            b = DTYPE_BYTES.get(dt, 4)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * b
        out[kind] = out.get(kind, 0) + nbytes
        out["count_" + kind] = out.get("count_" + kind, 0) + 1
    return out


def _jsonable(d):
    if isinstance(d, dict):
        return {k: _jsonable(v) for k, v in d.items()}
    if isinstance(d, (list, tuple)):
        return [_jsonable(v) for v in d]
    if isinstance(d, (int, str, bool)) or d is None:
        return d
    try:
        return float(d)
    except Exception:
        return str(d)


def dryrun_one(arch: str, shape_name: str, multi_pod: bool,
               objective: str = "lm", kv_dtype: str = "bf16") -> dict:
    import jax.numpy as _jnp
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": shape.kind, "objective": objective,
                 "kv_dtype": kv_dtype}
    cdt = _jnp.int8 if kv_dtype == "int8" else _jnp.bfloat16
    t0 = time.time()
    with compat.set_mesh(mesh):
        specs = st.input_specs(cfg, shape, mesh, cache_dtype=cdt)
        p_sds, _ = st.params_specs(cfg, mesh)
        # §Perf iteration 5: donate the aliasable state — params+momentum in
        # train, the KV cache in decode — so the updated copy reuses the
        # input buffers instead of doubling peak memory.
        if shape.kind == "train":
            fn, nm = st.make_train_step(cfg, shape, mesh, objective=objective)
            mom_sds = jax.tree.map(lambda s: s, p_sds)  # same shape/sharding
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(
                p_sds, mom_sds, specs)
            rec["n_micro"] = nm
        elif shape.kind == "prefill":
            fn = st.make_prefill_step(cfg, shape, mesh)
            lowered = jax.jit(fn).lower(p_sds, specs)
        else:
            fn = st.make_decode_step(cfg, shape, mesh)
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(p_sds, specs)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost"] = {k: float(v) for k, v in cost.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed", "optimal_seconds",
                             "bytes accessed output")}
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes(hlo)
        rec["hlo_lines"] = hlo.count("\n")
    rec["total_s"] = round(time.time() - t0, 2)
    rec["n_params"] = cfg.n_params()
    rec["n_active_params"] = cfg.n_active_params()
    return rec


def _depth_points(cfg):
    """Two reduced-depth full-width variants for scan-cost calibration."""
    if cfg.family == "vlm":
        per = cfg.cross_attn_period
        return per, 2 * per
    return 2, 4


def _at_depth(cfg, L: int):
    import dataclasses
    kw = {"n_layers": L}
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = L
    if cfg.moe_first_dense_layers:
        kw["moe_first_dense_layers"] = 1
    return dataclasses.replace(cfg, **kw)


def calibrate_one(arch: str, shape_name: str, multi_pod: bool,
                  objective: str = "lm") -> dict:
    """XLA cost_analysis counts while-loop (lax.scan) bodies ONCE, not
    x trip-count, so deep models under-report FLOPs/bytes/collectives by
    ~n_layers. Calibration: lower the SAME arch at two reduced depths
    (full width), take the per-layer increment, extrapolate to full depth:

        cost(L) ~= cost(L1) + (L - L1) * (cost(L2) - cost(L1)) / (L2 - L1)

    Calibration lowers with n_micro=1 (flops are micro-invariant at equal
    global batch) and with the layer / kv-chunk scans UNROLLED so every
    body instance is visible to the analyzer (see models/scan_ctx.py).
    The RWKV/SSM intra-layer time-chunk scans stay rolled — their
    recurrence FLOPs are <2% of the surrounding projections (noted in
    EXPERIMENTS.md §Roofline limitations).
    Enc-dec archs scale encoder+decoder depth together (both are 24 at
    full scale, so the shared multiplier is exact).
    """
    import dataclasses

    from repro.models import scan_ctx
    base = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    L1, L2 = _depth_points(base)
    costs = []
    for L in (L1, L2):
        cfg = _at_depth(base, L)
        with compat.set_mesh(mesh), scan_ctx.unrolled(layers=scan_ctx.FULL,
                                                   kv=scan_ctx.FULL):
            specs = st.input_specs(cfg, shape, mesh)
            p_sds, _ = st.params_specs(cfg, mesh)
            if shape.kind == "train":
                fn, _ = st.make_train_step(cfg, shape, mesh,
                                           objective=objective, n_micro=1)
                lowered = jax.jit(fn).lower(p_sds, p_sds, specs)
            elif shape.kind == "prefill":
                fn = st.make_prefill_step(cfg, shape, mesh)
                lowered = jax.jit(fn).lower(p_sds, specs)
            else:
                fn = st.make_decode_step(cfg, shape, mesh)
                lowered = jax.jit(fn).lower(p_sds, specs)
            compiled = lowered.compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            coll = collective_bytes(compiled.as_text())
            costs.append({
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": float(sum(v for k, v in coll.items()
                                  if not k.startswith("count_"))),
            })
    L = base.n_layers
    out = {}
    for k in ("flops", "bytes", "coll"):
        per_layer = (costs[1][k] - costs[0][k]) / (L2 - L1)
        out[k] = costs[0][k] + per_layer * (L - L1)
        out[k + "_per_layer"] = per_layer
    out["depth_points"] = [L1, L2]
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single,multi")
    ap.add_argument("--objective", default="lm")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--calibrate", action="store_true",
                    help="add depth-extrapolated cost estimates")
    ap.add_argument("--kv-dtype", default="bf16", choices=["bf16", "int8"])
    ap.add_argument("--out", default=os.path.join(RESULTS, "dryrun.json"))
    args = ap.parse_args()

    archs = (args.arch.split(",") if args.arch else
             [a for a in list_configs() if a != "resnet18-cifar"])
    shapes = args.shape.split(",") if args.shape else list(INPUT_SHAPES)
    meshes = args.mesh.split(",")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    results = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            results = json.load(f)

    for arch in archs:
        for shape in shapes:
            for m in meshes:
                key = f"{arch}|{shape}|{m}|{args.objective}"
                prev = results.get(key, {})
                done = prev.get("ok") and (not args.calibrate or
                                           "calibrated" in prev)
                if done and not args.force:
                    print(f"[skip] {key}")
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    if prev.get("ok") and args.calibrate and not args.force:
                        rec = dict(prev)
                    else:
                        rec = dryrun_one(arch, shape, m == "multi",
                                         args.objective, args.kv_dtype)
                    if args.calibrate:
                        rec["calibrated"] = calibrate_one(
                            arch, shape, m == "multi", args.objective)
                    rec["ok"] = True
                    print(f"  ok: compile={rec['compile_s']}s "
                          f"flops={rec['cost'].get('flops', 0):.3e} "
                          f"coll={sum(v for k, v in rec['collectives'].items() if not k.startswith('count_')):.3e}B",
                          flush=True)
                except Exception as e:
                    rec = {"ok": False, "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                    print(f"  FAIL: {rec['error']}", flush=True)
                results[key] = _jsonable(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)

    n_ok = sum(1 for r in results.values() if r.get("ok"))
    print(f"\n{n_ok}/{len(results)} combos OK -> {args.out}")


if __name__ == "__main__":
    main()
