"""Production mesh construction.

Target: TPU v5e pods — 16x16 = 256 chips per pod, 2 pods = 512 chips.
Axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The federated cohort axis of FLSimCo is ("pod", "data") — each cohort
(vehicle group) owns a batch slice; blur-weighted aggregation reduces over
those axes (DESIGN.md §2).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun.py forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names — lets the same pjit
    code run on the CPU container for integration tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch (and federated cohorts) shard over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, names) -> int:
    s = 1
    for n in ([names] if isinstance(names, str) else names):
        s *= mesh.shape[n]
    return s
