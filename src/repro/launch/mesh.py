"""Production mesh construction.

Target: TPU v5e pods — 16x16 = 256 chips per pod, 2 pods = 512 chips.
Axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
The federated cohort axis of FLSimCo is ("pod", "data") — each cohort
(vehicle group) owns a batch slice; blur-weighted aggregation reduces over
those axes (DESIGN.md §2).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (smoke tests see 1 CPU device; only dryrun.py forces 512).

Cohort meshes (DESIGN.md §Sharded cohorts): a stacked `CohortBatch` of
R RSUs x s vehicles shards its leading cohort axis over a
(pod=R, data=d) mesh with d | s, so every device owns a contiguous
rsu-aligned block of vehicles. `cohort_mesh` builds (and CACHES) that
mesh — `MultiRSU._mesh_aggregate` used to call `jax.make_mesh` every
round — and `maybe_cohort_mesh` is the auto-resolution the topologies
use to promote the sharded path to the default whenever >1 device is
visible.
"""
from __future__ import annotations

import functools

import jax

COHORT_AXES = ("pod", "data")

_FORCE_HINT = ("run under XLA_FLAGS=--xla_force_host_platform_device_count=N "
               "to force N host devices on CPU, or drop to the host path "
               "(mesh_aggregate=False)")


@functools.lru_cache(maxsize=64)
def _mesh_cached(shape: tuple, names: tuple):
    return jax.make_mesh(shape, names)


def cohort_mesh(pods: int, data: int):
    """The (pod=pods, data=data) mesh a stacked cohort shards over.

    Cached on the shape — building a `jax.make_mesh` per round (the old
    `MultiRSU._mesh_aggregate` behavior) re-enumerates devices every
    time. Raises with an actionable message (required vs available
    device counts + the CPU forcing hint) instead of jax's bare error.
    """
    if pods < 1 or data < 1:
        raise ValueError(f"cohort mesh axes must be >= 1, got "
                         f"(pod={pods}, data={data})")
    need, have = pods * data, jax.device_count()
    if have < need:
        raise ValueError(
            f"cohort mesh (pod={pods}, data={data}) needs {need} devices; "
            f"have {have} — {_FORCE_HINT}")
    return _mesh_cached((pods, data), COHORT_AXES)


def cohort_axis_divisor(rows_per_pod: int, pods: int,
                        device_count: int = None) -> int:
    """Largest d with d | rows_per_pod and pods * d <= device_count — the
    widest data axis that keeps every per-RSU block device-aligned
    without padding."""
    if device_count is None:
        device_count = jax.device_count()
    cap = max(1, device_count // max(pods, 1))
    for d in range(min(rows_per_pod, cap), 0, -1):
        if rows_per_pod % d == 0:
            return d
    return 1


def maybe_cohort_mesh(pods: int, rows_per_pod: int):
    """Auto-resolution for the default sharded path: the widest feasible
    (pod=pods, data=d) cohort mesh, or None when fewer than 2 devices
    are usable (the single-device host path stays the default there)."""
    if pods < 1 or rows_per_pod < 1:
        return None
    have = jax.device_count()
    if have < 2 or have < pods:
        return None
    d = cohort_axis_divisor(rows_per_pod, pods, have)
    if pods * d < 2:
        return None
    return cohort_mesh(pods, d)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    # analysis: allow=retrace-ctor -- launch-time setup, not per-round
    # (per-round meshes go through the lru_cached cohort_mesh below)
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh with the production axis names — lets the same pjit
    code run on the CPU container for integration tests."""
    # analysis: allow=retrace-ctor -- test-setup helper, not per-round
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch (and federated cohorts) shard over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, names) -> int:
    s = 1
    for n in ([names] if isinstance(names, str) else names):
        s *= mesh.shape[n]
    return s
