"""FL serving driver: train a campaign and serve the fleet in one process.

The real RSU deployment loop from the paper's setting — the aggregated
global model pushed down to moving vehicles — over the `repro.serve`
tier (ROADMAP item 3): `run_campaign(publish=store.publish)` is the
learner publishing one snapshot per round into a `ModelStore`;
`RSUServer` is the distribution actor answering concurrent vehicle
fetches with batched replies (delta chains through the `CODECS`
registry, full-tree staleness fallback) and admission control.

Fetcher threads simulate the fleet while the campaign trains: each
vehicle holds some already-fetched round, submits a fetch, applies the
reply payloads, and checks the decoded tree is BITWISE equal to the
snapshot the server reconstructs — the drive-by verification that the
serving path never forks the fleet. Exits non-zero if any request is
lost or any decode mismatches.

  PYTHONPATH=src python -m repro.launch.serve --rounds 6 --vehicles 200
  PYTHONPATH=src python -m repro.launch.serve --codec delta_int8 \
      --max-lag 2 --queue-limit 64        # exercise full fallback + shed
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from repro.core.scenario import Scenario, run_campaign
from repro.serve import ModelStore, RSUServer, ServePolicy, apply_reply


def _trees_equal(a, b) -> bool:
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _fetch_worker(server, store, codec, n_fetches, seed, out):
    rs = np.random.RandomState(seed)
    lat_us, mism, shed, served = [], 0, 0, 0
    have_round, have_tree = None, None
    for _ in range(n_fetches):
        rounds = store.rounds()
        if not rounds:
            time.sleep(0.001)
            continue
        if have_round is None or rs.rand() < 0.2:
            # (re)join the fleet at a random already-published round
            have_round = int(rs.choice(rounds))
            have_tree = store.get(have_round)
            have_tree = (None if have_tree is None
                         else have_tree.served_tree)
        pend = server.submit(have_round if have_tree is not None else -1)
        rep = pend.result(timeout=30.0)
        lat_us.append((time.perf_counter() - pend.t_submit) * 1e6)
        if rep.status == "shed":
            shed += 1
            time.sleep(rep.retry_after_s)
            continue
        served += 1
        have_tree = apply_reply(rep, have_tree, codec=codec)
        have_round = rep.round
        snap = store.get(rep.round)
        if snap is not None and not _trees_equal(have_tree,
                                                 snap.served_tree):
            mism += 1
    out.append({"lat_us": lat_us, "mismatches": mism, "shed": shed,
                "served": served})


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--vehicles", type=int, default=200,
                    help="total fetches issued across the fleet")
    ap.add_argument("--fetchers", type=int, default=8,
                    help="client threads simulating the fleet")
    ap.add_argument("--codec", default="delta",
                    choices=["identity", "delta", "delta_int8"])
    ap.add_argument("--max-lag", type=int, default=4)
    ap.add_argument("--queue-limit", type=int, default=4096)
    ap.add_argument("--window", type=int, default=16)
    a = ap.parse_args(argv)

    rs = np.random.RandomState(0)
    data = [rs.rand(6, 4, 4, 3).astype(np.float32) for _ in range(8)]
    sc = Scenario(topology="single", data=data, n_vehicles=8,
                  vehicles_per_round=3, batch_size=2, rounds=a.rounds,
                  local_iters=1, lr=0.4, seed=11)

    store = ModelStore(codec=a.codec, window=a.window)
    state0 = sc.init_state()
    store.publish(state0.round, state0.global_tree)   # round-0 bootstrap
    server = RSUServer(store, ServePolicy(max_lag=a.max_lag,
                                          queue_limit=a.queue_limit))

    per = max(1, a.vehicles // a.fetchers)
    out: list = []
    threads = [threading.Thread(target=_fetch_worker,
                                args=(server, store, a.codec, per, 100 + i,
                                      out))
               for i in range(a.fetchers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    state, _hist = run_campaign(sc, state0, publish=store.publish,
                                publish_every=1)
    for t in threads:
        t.join()
    server.stop()
    wall = time.perf_counter() - t0

    lat = np.concatenate([np.asarray(o["lat_us"]) for o in out])
    served = sum(o["served"] for o in out)
    shed = sum(o["shed"] for o in out)
    mism = sum(o["mismatches"] for o in out)
    st = server.stats()
    print(f"trained {a.rounds} rounds; published "
          f"{store.stats()['publishes']} snapshots (codec={a.codec})")
    print(f"served {served} fetches ({shed} shed) from "
          f"{a.fetchers} fetchers in {wall:.2f}s "
          f"-> {served / wall:.0f} models/s")
    print(f"fetch latency p50 {np.percentile(lat, 50):.0f} us, "
          f"p99 {np.percentile(lat, 99):.0f} us; "
          f"batches={st['batches']} groups={st['groups']} "
          f"max_depth={st['max_depth']}")
    lost = st["submitted"] - st["served"] - st["shed"]
    print(f"decode parity: {mism} mismatches; lost requests: {lost}")
    if mism or lost:
        raise SystemExit("FAIL: serve parity/accounting violated")
    assert state.round == state0.round + a.rounds
    print("OK")


if __name__ == "__main__":
    main()
