"""Parameter / activation / cache sharding rules.

Strategy (DESIGN.md §4): Megatron-style tensor parallelism on the `model`
axis + FSDP-style weight sharding on the ("pod","data") axes for the
multi-hundred-GB architectures, with per-tensor divisibility checks that
degrade gracefully to replication (hymba's 25 heads, qwen2's kv=2, ...).

Everything funnels through `sanitize`, which drops any axis that does not
divide the corresponding tensor dimension — so every (arch x shape x mesh)
combination lowers, and the roofline report shows the cost of whatever had
to be replicated.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import batch_axes


def _size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def sanitize(mesh, spec: P, shape) -> P:
    """Drop spec axes that don't divide the tensor dim; keeps the rest."""
    out = []
    for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if axes is None:
            out.append(None)
            continue
        if dim % _size(mesh, axes) == 0:
            out.append(axes)
        elif not isinstance(axes, str):  # try a prefix of the tuple
            kept = []
            for a in axes:
                if dim % _size(mesh, tuple(kept) + (a,)) == 0:
                    kept.append(a)
            out.append(tuple(kept) if kept else None)
        else:
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def param_spec(mesh, path: str, shape, *, fsdp: bool = True,
               stacked_prefix: int = 0) -> P:
    """PartitionSpec for one parameter leaf.

    `stacked_prefix` = number of leading stacked-layer axes (kept
    unsharded). `fsdp=True` adds ("pod","data") sharding on the non-model
    dim of large 2-D weights.
    """
    fs = batch_axes(mesh) if fsdp else None
    core = shape[stacked_prefix:]
    nd = len(core)

    def with_prefix(*spec):
        return P(*((None,) * stacked_prefix + spec))

    last = path.rsplit("/", 1)[-1]

    # --- embeddings / unembed ------------------------------------------------
    if last == "embed":                          # (V, d)
        return sanitize(mesh, with_prefix("model", fs), shape)
    if last == "unembed":                        # (d, V)
        return sanitize(mesh, with_prefix(fs, "model"), shape)
    if last in ("vision_proj", "audio_adapter"):
        return sanitize(mesh, with_prefix(None, "model"), shape)

    # --- MoE -----------------------------------------------------------------
    if last == "router":                         # (d, E)
        return sanitize(mesh, with_prefix(None, "model"), shape)
    if "moe" in path and last in ("w_up", "w_gate", "w_down") and nd == 3:
        # (E, d, f) / (E, f, d): expert-parallel on model, FSDP on dim 1
        return sanitize(mesh, with_prefix("model", fs, None), shape)

    # --- attention -----------------------------------------------------------
    if last in ("wq", "wk", "wv"):               # (d, H*hd)
        return sanitize(mesh, with_prefix(fs, "model"), shape)
    if last == "wo":                             # (H*hd, d)
        return sanitize(mesh, with_prefix("model", fs), shape)
    if last in ("bq", "bk", "bv"):
        return sanitize(mesh, with_prefix("model"), shape)

    # --- MLP -----------------------------------------------------------------
    if last in ("w_up", "w_gate"):               # (d, f)
        return sanitize(mesh, with_prefix(fs, "model"), shape)
    if last == "w_down":                         # (f, d)
        return sanitize(mesh, with_prefix("model", fs), shape)

    # --- rwkv ----------------------------------------------------------------
    if last in ("wr", "wk", "wv", "wg"):         # (d, d) — caught above for attn
        return sanitize(mesh, with_prefix(fs, "model"), shape)
    if last == "w_lora_a":
        return sanitize(mesh, with_prefix(fs, None), shape)
    if last == "w_lora_b":
        return sanitize(mesh, with_prefix(None, "model"), shape)

    # --- ssm -----------------------------------------------------------------
    if last == "w_in":                           # (d, 2*di)
        return sanitize(mesh, with_prefix(fs, "model"), shape)
    if last == "w_out":                          # (di, d)
        return sanitize(mesh, with_prefix("model", fs), shape)
    if last in ("w_dt",):                        # (di, di)
        return sanitize(mesh, with_prefix(fs, "model"), shape)
    if last in ("w_B", "w_C"):                   # (di, st)
        return sanitize(mesh, with_prefix("model", None), shape)
    if last in ("A_log", "D", "b_dt"):           # (di, st)/(di,)
        return sanitize(mesh, with_prefix("model"), shape)
    if last == "conv":                           # (4, di)
        return sanitize(mesh, with_prefix(None, "model"), shape)

    # --- projector / probe / small ------------------------------------------
    if nd == 2 and min(core) >= 128:
        return sanitize(mesh, with_prefix(fs, "model"), shape)
    return P()  # norms, biases, scalars, mu, u, w0 — replicated


def params_shardings(mesh, params, *, fsdp: bool = True, vlm: bool = False):
    """NamedSharding pytree matching `params` (from transformer.init_params)."""
    def one(path, leaf):
        ps = _path_str(path)
        stacked = 0
        if ps.startswith(("blocks", "dense_blocks", "cross_blocks", "enc_blocks")):
            stacked = 1
            if vlm and ps.startswith("blocks/"):
                stacked = 2                       # (n_super, n_self, ...)
        spec = param_spec(mesh, ps, leaf.shape, fsdp=fsdp,
                          stacked_prefix=stacked)
        return NamedSharding(mesh, sanitize(mesh, spec, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, params)


# --------------------------------------------------------------------------
# data / cache shardings
# --------------------------------------------------------------------------

def batch_spec(mesh, global_batch: int) -> P:
    """Shard batch over (pod,data) when divisible, else replicate."""
    ba = batch_axes(mesh)
    if global_batch % _size(mesh, ba) == 0:
        return P(ba)
    # try data-only
    if "data" in ba and global_batch % mesh.shape["data"] == 0:
        return P("data")
    return P(None)


def tokens_sharding(mesh, global_batch: int):
    return NamedSharding(mesh, P(*batch_spec(mesh, global_batch), None))


def kv_cache_spec(mesh, shape, bax, prefix: int = 1) -> P:
    """Preference chain for (L?, B, W, KH, hd) KV buffers: heads on model
    if divisible, else head_dim, else the W axis, else replicate. Shared
    between cache_shardings and the attention activation rule so the
    decode path never reshards the cache (§Perf iteration 6)."""
    pre = (None,) * prefix
    # preference: heads (no collective at all) > W (contraction dim —
    # GSPMD turns the QK/PV dots into partial-sum + all-reduce instead of
    # replicating the cache) > head_dim
    for cand in (P(*pre, bax, None, "model", None),
                 P(*pre, bax, "model", None, None),
                 P(*pre, bax, None, None, "model")):
        if sanitize(mesh, cand, shape) == cand:
            return cand
    return sanitize(mesh, P(*pre, bax, None, None, None), shape)


def cache_shardings(mesh, cache, global_batch: int):
    """KV cache: batch on (pod,data); heads on model if divisible, else
    head_dim, else the W (window/seq) axis; SSM/rwkv states: channel axis."""
    b = batch_spec(mesh, global_batch)
    bax = b[0] if len(b) else None

    def one(path, leaf):
        ps = _path_str(path)
        shp = leaf.shape
        last = ps.rsplit("/", 1)[-1]
        if last in ("k", "v"):                    # (L, B, W, KH, hd)
            return NamedSharding(mesh, kv_cache_spec(mesh, shp, bax))
        if last in ("k_scale", "v_scale"):        # (L, B, W, KH)
            full = kv_cache_spec(mesh, shp + (1,), bax)
            return NamedSharding(mesh, sanitize(mesh, P(*tuple(full)[:4]), shp))
        if last == "pos":                         # (L, B, W)
            return NamedSharding(mesh, sanitize(mesh, P(None, bax, None), shp))
        if last == "state" and leaf.ndim == 5:    # rwkv (L,B,H,D,D)
            return NamedSharding(mesh, sanitize(
                mesh, P(None, bax, "model", None, None), shp))
        if last == "ssm":                         # (L,B,di,st)
            return NamedSharding(mesh, sanitize(
                mesh, P(None, bax, "model", None), shp))
        if last == "conv":                        # (L,B,3,di)
            return NamedSharding(mesh, sanitize(
                mesh, P(None, bax, None, "model"), shp))
        if last in ("x_last_t", "x_last_c"):      # (L,B,d)
            return NamedSharding(mesh, sanitize(mesh, P(None, bax, None), shp))
        if last == "ctx":                         # (B, T, d)
            return NamedSharding(mesh, sanitize(mesh, P(bax, None, None), shp))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, cache)


# --------------------------------------------------------------------------
# activation rules for sharding_hooks
# --------------------------------------------------------------------------

def make_activation_rules(mesh, global_batch: int):
    """Returns constrain(x, name) for repro.models.sharding_hooks."""
    from jax.lax import with_sharding_constraint
    b = batch_spec(mesh, global_batch)
    bax = b[0] if len(b) else None
    table = {
        "tokens_bsd": P(bax, None, None),
        "tokens_bsf": P(bax, None, "model"),
        "attn_bshd": P(bax, None, "model", None),
        "moe_ecd": P("model", None, None),
        "logits_bsv": P(bax, None, "model"),
    }

    def constrain(x, name):
        if name == "cache_kv":                   # (B, W, KH, hd)
            spec = kv_cache_spec(mesh, x.shape, bax, prefix=0)
            return with_sharding_constraint(x, NamedSharding(mesh, spec))
        spec = table.get(name)
        if spec is None:
            return x
        spec = sanitize(mesh, spec, x.shape)
        return with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain
