"""pjit-ready train / serve steps for every (arch x input-shape) combo.

Federated mapping (DESIGN.md §2): cohorts of vehicles live on the
("pod","data") mesh axes. For the paper's default of one local iteration,
FLSimCo's Eq. 11 aggregation is *exactly* a blur-weighted gradient
all-reduce:

    theta' = sum_n w_n (theta - eta g_n) = theta - eta sum_n w_n g_n

so the production train_step weights each example's loss by its cohort's
normalized Eq.-11 weight and lets GSPMD emit the weighted all-reduce —
the technique becomes one collective instead of an RSU gather/scatter.
(The multi-local-iteration divergent form is validated against this and
against host-level aggregation in tests/test_collective_agg.py via
shard_map.)

Memory: gradient accumulation over microbatches (scan) keeps activation
checkpoints bounded; scan-over-layers already checkpoints per layer.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.launch import sharding as sh
from repro.launch.mesh import axis_size, batch_axes
from repro.models import transformer as T
from repro.models.sharding_hooks import activation_sharding

MASK_TOKEN = 0  # token id used for DT-objective masking views


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardings attached)
# --------------------------------------------------------------------------

def _aux_shapes(cfg: ModelConfig, B: int, S: int) -> dict:
    if cfg.family == "vlm":
        return {"patches": ((B, cfg.n_vision_tokens, cfg.d_vision), jnp.bfloat16)}
    if cfg.family == "audio":
        return {"frames": ((B, max(S // 4, 8), cfg.d_audio), jnp.bfloat16)}
    return {}


def enc_ctx_len(cfg: ModelConfig, S: int) -> int:
    if cfg.family == "vlm":
        return cfg.n_vision_tokens
    if cfg.family == "audio":
        return max(S // 4, 8)
    return 0


def input_specs(cfg: ModelConfig, shape: InputShape, mesh,
                param_dtype=jnp.bfloat16, cache_dtype=None) -> dict:
    """ShapeDtypeStructs (with shardings) for one workload.

    train:   {"tokens","blur",aux...}
    prefill: {"tokens",aux...}
    decode:  {"tokens","positions","cache"}
    """
    B, S = shape.global_batch, shape.seq_len
    tok_sh = sh.tokens_sharding(mesh, B)
    bspec = sh.batch_spec(mesh, B)
    bax = bspec[0] if len(bspec) else None

    def sds(shp, dt, spec):
        return jax.ShapeDtypeStruct(shp, dt, sharding=NamedSharding(
            mesh, sh.sanitize(mesh, spec, shp)))

    if shape.kind == "train":
        out = {
            "tokens": sds((B, S), jnp.int32, P(bax, None)),
            "blur": sds((B,), jnp.float32, P(bax)),
        }
        for name, (shp, dt) in _aux_shapes(cfg, B, S).items():
            out[name] = sds(shp, dt, P(bax, None, None))
        return out

    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32, P(bax, None))}
        for name, (shp, dt) in _aux_shapes(cfg, B, S).items():
            out[name] = sds(shp, dt, P(bax, None, None))
        return out

    # decode: one token against a cache of S positions
    long_ctx = shape.name == "long_500k"
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, B, S, dtype=cache_dtype or param_dtype,
                             long_context=long_ctx,
                             ctx_len=enc_ctx_len(cfg, S)))
    cache_sh = sh.cache_shardings(mesh, cache, B)
    cache_sds = jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        cache, cache_sh)
    return {
        "tokens": sds((B, 1), jnp.int32, P(bax, None)),
        "positions": sds((B,), jnp.int32, P(bax)),
        "cache": cache_sds,
    }


def params_specs(cfg: ModelConfig, mesh, param_dtype=jnp.bfloat16):
    """ShapeDtypeStructs + shardings for the parameter tree."""
    p_shape = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype=param_dtype))
    p_shard = sh.params_shardings(mesh, p_shape, vlm=cfg.family == "vlm")
    sds = jax.tree.map(lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                                         sharding=s),
                       p_shape, p_shard)
    return sds, p_shard


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def _flsimco_example_weights(blur):
    """Eq. 11 weights across the global batch, normalized to sum to 1."""
    total = jnp.sum(blur)
    w = (total - blur) / jnp.maximum(total, 1e-12)
    return w / jnp.maximum(jnp.sum(w), 1e-12)


def lm_loss_per_example(cfg, logits, tokens, mode: str = "onehot"):
    """Next-token CE per example (B,) — f32, padded vocab already masked.

    mode="onehot" (default, §Perf iteration 1): the target logit is picked
    with a one-hot einsum that XLA fuses into an iota-compare — the vocab
    axis stays `model`-sharded through the whole loss (logsumexp reduces
    over the sharded axis with a scalar-sized all-reduce). mode="gather"
    (the pre-optimization baseline) uses take_along_axis, which GSPMD can
    only partition by replicating the (B,S,V) f32 logits on every device —
    measured 13x higher HBM traffic on qwen2-0.5b train_4k.
    """
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    if mode == "gather":
        lp = jax.nn.log_softmax(lg, axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        return nll.mean(axis=-1)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(tgt, lg.shape[-1], dtype=lg.dtype)
    tgt_logit = jnp.einsum("bsv,bsv->bs", lg, onehot)
    return (lse - tgt_logit).mean(axis=-1)


def dt_objective(cfg, params, tokens, key, aux_inputs=None,
                 tau_alpha=0.1, tau_beta=1.0):
    """Token-view DT-SSL objective (framework extension, DESIGN.md §2)."""
    from repro.core.dt_loss import dt_loss_matrix
    k1, k2 = jax.random.split(key)
    drop1 = jax.random.bernoulli(k1, 0.15, tokens.shape)
    drop2 = jax.random.bernoulli(k2, 0.15, tokens.shape)
    v1 = jnp.where(drop1, MASK_TOKEN, tokens)
    v2 = jnp.where(drop2, MASK_TOKEN, tokens)
    q, aux1 = T.forward_features(cfg, params, v1, aux_inputs=aux_inputs)
    k, aux2 = T.forward_features(cfg, params, v2, aux_inputs=aux_inputs)
    return dt_loss_matrix(q, k, tau_alpha, tau_beta) + aux1 + aux2


# --------------------------------------------------------------------------
# train step
# --------------------------------------------------------------------------

def pick_n_micro(cfg: ModelConfig, shape: InputShape, mesh,
                 act_budget_bytes: float = 4e9) -> int:
    """Gradient-accumulation factor: keep per-layer activation checkpoints
    (the dominant train-memory term under scan-over-layers) under budget."""
    shards = axis_size(mesh, batch_axes(mesh))
    b_loc = max(shape.global_batch // shards, 1)
    per_sample = cfg.n_layers * shape.seq_len * cfg.d_model * 2  # bf16
    need = per_sample * b_loc / act_budget_bytes
    n = 1
    while n < b_loc and need / n > 1.0:
        n *= 2
    return min(n, b_loc)


def make_train_step(cfg: ModelConfig, shape: InputShape, mesh, *,
                    objective: str = "lm", optimizer: str = "sgdm",
                    lr: float = 1e-2, momentum: float = 0.9,
                    weight_decay: float = 5e-4, aggregation: str = "flsimco",
                    n_micro: Optional[int] = None, ce_mode: str = "onehot"):
    """Returns train_step(params, mom, batch) -> (params, mom, metrics).

    The blur-weighted Eq.-11 aggregation is realized as per-example loss
    weights (see module docstring); `aggregation="fedavg"` degenerates to
    uniform weights, "discard" zeroes examples past the blur threshold.
    """
    from repro.core.mobility import BLUR_KMH_100
    nm = n_micro or pick_n_micro(cfg, shape, mesh)
    constrain = sh.make_activation_rules(mesh, shape.global_batch)

    def example_weights(blur):
        if aggregation == "flsimco":
            return _flsimco_example_weights(blur)
        if aggregation == "discard":
            keep = (blur <= BLUR_KMH_100).astype(jnp.float32)
            return keep / jnp.maximum(keep.sum(), 1.0)
        return jnp.full_like(blur, 1.0 / blur.shape[0])

    def loss_fn(params, micro_batch):
        tokens = micro_batch["tokens"]
        aux_in = {k: v for k, v in micro_batch.items()
                  if k in ("frames", "patches")} or None
        if objective == "dt":
            key = jax.random.PRNGKey(0)  # deterministic views for lowering
            loss = dt_objective(cfg, params, tokens, key, aux_in)
            return loss
        logits, _, aux = T.forward(cfg, params, tokens, mode="train",
                                   aux_inputs=aux_in)
        per_ex = lm_loss_per_example(cfg, logits, tokens, mode=ce_mode)
        # blur-weighted aggregation as example weights (x global batch so
        # the mean-of-microbatch-sums matches the global weighted sum)
        w = micro_batch["weights"]
        return jnp.sum(per_ex * w) + aux

    def train_step(params, mom, batch):
        weights = example_weights(batch["blur"])
        batch = dict(batch, weights=weights)
        del batch["blur"]

        def micro_grads(mb):
            with activation_sharding(constrain):
                return jax.value_and_grad(loss_fn)(params, mb)

        if nm == 1:
            loss, grads = micro_grads(batch)
        else:
            split = jax.tree.map(
                lambda x: x.reshape((nm, x.shape[0] // nm) + x.shape[1:]), batch)

            def acc(carry, mb):
                l, g = micro_grads(mb)
                return (carry[0] + l, jax.tree.map(jnp.add, carry[1], g)), None

            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(acc, zero, split)

        def upd(p, g, m):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            if optimizer == "sgdm":
                m_new = momentum * m.astype(jnp.float32) + g
                return ((p.astype(jnp.float32) - lr * m_new).astype(p.dtype),
                        m_new.astype(m.dtype))
            return (p.astype(jnp.float32) - lr * g).astype(p.dtype), m

        pairs = jax.tree.map(upd, params, grads, mom)
        leaf = lambda t: isinstance(t, tuple)
        new_p = jax.tree.map(lambda t: t[0], pairs, is_leaf=leaf)
        new_m = jax.tree.map(lambda t: t[1], pairs, is_leaf=leaf)
        return new_p, new_m, {"loss": loss}

    return train_step, nm


def init_momentum(params, optimizer: str = "sgdm"):
    if optimizer == "sgdm":
        return jax.tree.map(jnp.zeros_like, params)
    return jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params)


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, shape: InputShape, mesh,
                      param_dtype=jnp.bfloat16):
    constrain = sh.make_activation_rules(mesh, shape.global_batch)
    long_ctx = shape.name == "long_500k"

    def prefill(params, batch):
        tokens = batch["tokens"]
        aux_in = {k: v for k, v in batch.items()
                  if k in ("frames", "patches")} or None
        cache = T.init_cache(cfg, tokens.shape[0], shape.seq_len,
                             dtype=param_dtype, long_context=long_ctx,
                             ctx_len=enc_ctx_len(cfg, shape.seq_len))
        with activation_sharding(constrain):
            logits, cache, _ = T.forward(cfg, params, tokens, mode="prefill",
                                         cache=cache, aux_inputs=aux_in,
                                         long_context=long_ctx)
        return logits[:, -1], cache

    return prefill


def make_decode_step(cfg: ModelConfig, shape: InputShape, mesh):
    constrain = sh.make_activation_rules(mesh, shape.global_batch)
    long_ctx = shape.name == "long_500k"

    def decode(params, batch):
        with activation_sharding(constrain):
            logits, cache, _ = T.forward(
                cfg, params, batch["tokens"], mode="decode",
                cache=batch["cache"], positions=batch["positions"],
                long_context=long_ctx)
        return logits[:, 0], cache

    return decode
