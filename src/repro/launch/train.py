"""Production training driver.

Two modes, one experiment vocabulary:

``--mode mesh`` (default) — the TPU path: builds the production mesh,
shards params per launch/sharding.py, and runs the federated train step
(blur-weighted aggregation collective). On this CPU container
``--reduced`` runs real steps of the same code on the 1-device host
mesh; without it the driver lowers+compiles only (the multi-pod dry-run
path lives in dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 3 --objective lm

``--mode sim`` — the host-level FL simulation, declared as the same
`Scenario` the examples and benchmarks use and driven through the pure
`run_round` API, with full-`FLState` checkpoint/resume:

  PYTHONPATH=src python -m repro.launch.train --mode sim --topology multi \
      --rounds 4 --vehicles 8 --ckpt-dir /tmp/flsim --resume
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import INPUT_SHAPES, InputShape, get_config
from repro.core.mobility import MobilityModel
from repro.launch import steps as st
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import transformer as T


def run_sim(a) -> None:
    """Scenario-driven FL simulation with FLState checkpointing."""
    import os

    from repro.checkpoint.store import latest, restore_state, save_state
    from repro.core.scenario import Scenario, run_round

    sc = Scenario(topology=a.topology, aggregator=a.aggregation,
                  client=a.client, partitioner=a.partitioner,
                  n_per_class=a.n_per_class,
                  n_vehicles=a.vehicles, vehicles_per_round=a.per_round,
                  batch_size=a.batch, rounds=a.rounds, lr=a.sim_lr)
    state = None
    if a.resume and a.ckpt_dir:
        found = latest(a.ckpt_dir)
        if found:
            state = restore_state(found[0], scenario=sc)
            print(f"resumed FLState from {found[0]} (round {state.round})")
    if state is None:
        state = sc.init_state()
    print(f"sim {sc.topology.name} agg={sc.cfg.aggregator} "
          f"client={sc.cfg.client} vehicles={sc.cfg.n_vehicles} "
          f"rounds={sc.cfg.rounds}")
    while state.round < sc.cfg.rounds:
        t0 = time.time()
        state, rec = run_round(state, sc)
        print(f"round {rec['round']}: loss={rec['loss']:.4f} "
              f"({time.time()-t0:.2f}s)")
        assert np.isfinite(rec["loss"])
        if a.ckpt_dir:
            save_state(os.path.join(a.ckpt_dir,
                                    f"ckpt_{state.round}.npz"), state,
                       scenario=sc)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="mesh", choices=["mesh", "sim"])
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--objective", default="lm", choices=["lm", "dt"])
    ap.add_argument("--aggregation", default="flsimco",
                    choices=["flsimco", "fedavg", "discard"])
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--multi-pod", action="store_true")
    # --mode sim knobs (Scenario fields)
    ap.add_argument("--topology", default="single",
                    choices=["single", "multi", "handover"])
    ap.add_argument("--client", default="dtssl", choices=["dtssl", "fedco"])
    ap.add_argument("--partitioner", default="iid",
                    choices=["iid", "dirichlet"])
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--vehicles", type=int, default=6)
    ap.add_argument("--per-round", type=int, default=2)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--n-per-class", type=int, default=40)
    ap.add_argument("--sim-lr", type=float, default=0.5)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--resume", action="store_true")
    a = ap.parse_args()

    if a.mode == "sim":
        run_sim(a)
        return

    cfg = get_config(a.arch)
    if a.reduced:
        cfg = cfg.reduced()
        mesh = make_host_mesh()
        shape = InputShape("cpu", 64, 4, "train")
    else:
        mesh = make_production_mesh(multi_pod=a.multi_pod)
        shape = INPUT_SHAPES[a.shape]

    fn, nm = st.make_train_step(cfg, shape, mesh, objective=a.objective,
                                lr=a.lr, aggregation=a.aggregation)
    print(f"train {cfg.name} shape={shape.name} mesh={dict(mesh.shape)} "
          f"micro={nm} objective={a.objective} agg={a.aggregation}")

    if not a.reduced:
        specs = st.input_specs(cfg, shape, mesh)
        p_sds, _ = st.params_specs(cfg, mesh)
        with compat.set_mesh(mesh):
            lowered = jax.jit(fn).lower(p_sds, p_sds, specs)
            compiled = lowered.compile()
        print(compiled.memory_analysis())
        return

    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    mom = st.init_momentum(params)
    mob = MobilityModel()
    jfn = jax.jit(fn)
    with compat.set_mesh(mesh):
        for step in range(a.steps):
            k = jax.random.fold_in(key, step)
            batch = {"tokens": jax.random.randint(
                k, (shape.global_batch, shape.seq_len), 1, cfg.vocab_size),
                "blur": mob.blur_level(mob.sample(k, shape.global_batch))}
            if cfg.family == "vlm":
                batch["patches"] = jax.random.normal(
                    k, (shape.global_batch, cfg.n_vision_tokens, cfg.d_vision))
            if cfg.family == "audio":
                batch["frames"] = jax.random.normal(
                    k, (shape.global_batch, max(shape.seq_len // 4, 8),
                        cfg.d_audio))
            t0 = time.time()
            params, mom, metrics = jfn(params, mom, batch)
            loss = float(metrics["loss"])
            print(f"step {step}: loss={loss:.4f} ({time.time()-t0:.2f}s)")
            assert np.isfinite(loss)


if __name__ == "__main__":
    main()
