"""Pure-JAX neural-net primitives for the model zoo.

Everything is functional: ``init_*`` builds a param dict, the matching
apply function consumes it. No framework dependency (no flax/optax in this
container) — params are nested dicts of jax.Arrays, optimizers live in
``repro.optim``.

Numerics conventions:
  * params kept in caller-chosen dtype (f32 on CPU tests, bf16 for dry-run)
  * attention logits/softmax and norm statistics always computed in f32
  * masking uses a large-negative finite constant (NEG_INF) so fully-masked
    rows degrade to zeros instead of NaNs
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.models import scan_ctx
from repro.models.sharding_hooks import constrain

NEG_INF = -1e30
BIG_WINDOW = 1 << 30  # "no sliding window"; lets window be a traced scalar


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def normal_init(key, shape, std, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def fan_in_init(key, shape, dtype=jnp.float32):
    """Truncated-normal-ish scaled by 1/sqrt(fan_in) (first axis = fan_in)."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    return normal_init(key, shape, 1.0 / math.sqrt(fan_in), dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def init_rmsnorm(d, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)


def init_layernorm(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_norm(cfg, d=None, dtype=jnp.float32):
    d = d or cfg.d_model
    return init_rmsnorm(d, dtype) if cfg.norm == "rmsnorm" else init_layernorm(d, dtype)


def apply_norm(cfg, p, x):
    fn = rmsnorm if "bias" not in p else layernorm
    return fn(p, x, cfg.norm_eps)


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------

def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": partial(jax.nn.gelu, approximate=True),
        "relu": jax.nn.relu,
        "sqrelu": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                              # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv     # (..., S, D/2)
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention core — direct and kv-chunked (flash-style) paths
# --------------------------------------------------------------------------

def _softcap(s, cap):
    return cap * jnp.tanh(s / cap) if cap else s


def _build_mask(q_pos, kv_pos, *, causal, window):
    """(..., Sq, Sk) boolean visibility mask.

    q_pos: (B, Sq) ; kv_pos: (B, Sk) — kv_pos < 0 marks invalid slots.
    window may be a python int or a traced scalar (per-layer local/global
    alternation scans over layers); BIG_WINDOW disables it.
    """
    d = q_pos[..., :, None] - kv_pos[..., None, :]          # (B, Sq, Sk)
    mask = kv_pos[..., None, :] >= 0
    if causal:
        mask &= d >= 0
    mask &= d < window
    return mask


def _attn_direct(q, k, v, mask, *, scale, softcap):
    """q: (B,Sq,KH,G,D)  k,v: (B,Sk,KH,D)  mask: (B,Sq,Sk) -> (B,Sq,KH,G,D)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = _softcap(s * scale, softcap)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


# --------------------------------------------------------------------------
# flash attention with custom VJP — §Perf iteration 3
#
# Without it, XLA saves the (B, KH, G, Sq, Sk) f32 probability tensor per
# layer for the backward pass (measured 722 GB/device on qwen2 train_4k).
# The custom VJP saves only (o, lse) — O(S*d) — and recomputes chunk-sized
# score tiles in the backward scan, flash-attention style.
#
# q_pos / kv_pos / window travel as f32 so their (zero) cotangents are
# well-typed through custom_vjp.
# --------------------------------------------------------------------------

def _flash_mask(q_posf, kv_posf, *, causal, windowf):
    d = q_posf[..., :, None] - kv_posf[..., None, :]
    mask = kv_posf[..., None, :] >= 0
    if causal:
        mask &= d >= 0
    mask &= d < windowf
    return mask


def _flash_fwd_scan(qg, k, v, q_posf, kv_posf, windowf, causal, scale,
                    softcap, chunk):
    B, Sk, KH, D = k.shape
    _, Sq, _, G, _ = qg.shape
    n = Sk // chunk
    kc = k.reshape(B, n, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    pc = kv_posf.reshape(B, n, chunk).transpose(1, 0, 2)
    qf = qg.astype(jnp.float32)

    def body(carry, xs):
        m, l, acc = carry
        kci, vci, pci = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kci.astype(jnp.float32))
        s = _softcap(s * scale, softcap)
        mask = _flash_mask(q_posf, pci, causal=causal, windowf=windowf)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - m_new[..., None]))
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vci.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KH, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, KH, G, Sq, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc),
                                  unroll=scan_ctx.resolve("kv", n))
    l_safe = jnp.maximum(l, 1e-20)
    o = acc / l_safe[..., None]                              # (B,KH,G,Sq,D)
    lse = m + jnp.log(l_safe)
    return o, lse


@partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def flash_attention(qg, k, v, q_posf, kv_posf, windowf, causal, scale,
                    softcap, chunk):
    """qg: (B,Sq,KH,G,D); k/v: (B,Sk,KH,D); positions/window as f32.
    Returns (B,Sq,KH,G,D) in qg.dtype."""
    o, _ = _flash_fwd_scan(qg, k, v, q_posf, kv_posf, windowf, causal,
                           scale, softcap, chunk)
    return o.transpose(0, 3, 1, 2, 4).astype(qg.dtype)


def _flash_fwd(qg, k, v, q_posf, kv_posf, windowf, causal, scale, softcap,
               chunk):
    o, lse = _flash_fwd_scan(qg, k, v, q_posf, kv_posf, windowf, causal,
                             scale, softcap, chunk)
    out = o.transpose(0, 3, 1, 2, 4).astype(qg.dtype)
    return out, (qg, k, v, q_posf, kv_posf, windowf, o, lse)


def _flash_bwd(causal, scale, softcap, chunk, res, g):
    qg, k, v, q_posf, kv_posf, windowf, o, lse = res
    B, Sk, KH, D = k.shape
    _, Sq, _, G, _ = qg.shape
    n = Sk // chunk
    qf = qg.astype(jnp.float32)
    do = g.astype(jnp.float32).transpose(0, 2, 3, 1, 4)      # (B,KH,G,Sq,D)
    delta = jnp.sum(do * o, axis=-1)                         # (B,KH,G,Sq)
    kc = k.reshape(B, n, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n, chunk, KH, D).transpose(1, 0, 2, 3, 4)
    pc = kv_posf.reshape(B, n, chunk).transpose(1, 0, 2)

    def body(dq, xs):
        kci, vci, pci = xs
        kf = kci.astype(jnp.float32)
        s_raw = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) * scale
        s = _softcap(s_raw, softcap)
        mask = _flash_mask(q_posf, pci, causal=causal, windowf=windowf)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        p = jnp.where(s <= NEG_INF / 2, 0.0, jnp.exp(s - lse[..., None]))
        dv = jnp.einsum("bhgqk,bhgqd->bkhd", p, do)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", do, vci.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if softcap:
            ds = ds * (1.0 - jnp.square(jnp.tanh(s_raw / softcap)))
        ds = ds * scale
        dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kf)
        dk = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qf)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, KH, G, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kc, vc, pc),
                                  unroll=scan_ctx.resolve("kv", n))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KH, D).astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KH, D).astype(v.dtype)
    return (dq.astype(qg.dtype), dk, dv,
            jnp.zeros_like(q_posf), jnp.zeros_like(kv_posf),
            jnp.zeros_like(windowf))


flash_attention.defvjp(_flash_fwd, _flash_bwd)

FLASH_MIN_SQ = 2048  # use flash (chunk-recompute) path at/above this size


def attention_core(q, k, v, q_pos, kv_pos, *, causal=True, window=None,
                   scale=None, softcap=0.0, chunk=1024):
    """GQA attention. q: (B,Sq,H,D) -> out (B,Sq,H,D). k/v: (B,Sk,KH,D).

    `window` may be a python int, a traced scalar, or None (no window).
    Sq >= FLASH_MIN_SQ and chunk-aligned Sk -> flash path (custom-VJP,
    never materializes or saves (Sq, Sk) scores); otherwise the direct
    path (decode steps, short sequences, smoke tests).
    """
    if window is None:
        window = BIG_WINDOW
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D)
    scale = scale if scale else 1.0 / math.sqrt(D)
    Sk = k.shape[1]
    if Sq >= FLASH_MIN_SQ and Sk % chunk == 0:
        wf = jnp.asarray(window, jnp.float32)
        o = flash_attention(qg, k, v, q_pos.astype(jnp.float32),
                            kv_pos.astype(jnp.float32), wf, causal, scale,
                            softcap, chunk)
    else:
        mask = _build_mask(q_pos, kv_pos, causal=causal, window=window)
        o = _attn_direct(qg, k, v, mask, scale=scale, softcap=softcap)
    return o.reshape(B, Sq, H, D)


# --------------------------------------------------------------------------
# attention block (projections + rope + cache handling)
# --------------------------------------------------------------------------

def init_attention(cfg, key, dtype=jnp.float32, cross=False):
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": fan_in_init(ks[0], (d, H * hd), dtype),
        "wk": fan_in_init(ks[1], (d, KH * hd), dtype),
        "wv": fan_in_init(ks[2], (d, KH * hd), dtype),
        "wo": fan_in_init(ks[3], (H * hd, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KH * hd,), dtype)
        p["bv"] = jnp.zeros((KH * hd,), dtype)
    return p


def attention_block(cfg, p, x, q_pos, *, causal=True, window=None,
                    cache=None, kv_src=None, use_rope=True):
    """Self- or cross-attention with optional ring-buffer KV cache.

    x: (B, Sq, d).  q_pos: (B, Sq) absolute positions.
    kv_src: encoder/vision context (B, Sk, d) for cross-attention.
    cache: None, or dict(k=(B,W,KH,hd), v=..., pos=(B,W) int32) — updated
      ring buffer is returned; W is the buffer size (seq_len or window).
    Returns (out (B,Sq,d), new_cache_or_None).
    """
    B, Sq, d = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    src = x if kv_src is None else kv_src
    q = (x @ p["wq"] + p.get("bq", 0)).reshape(B, Sq, H, hd)
    k = (src @ p["wk"] + p.get("bk", 0)).reshape(B, src.shape[1], KH, hd)
    v = (src @ p["wv"] + p.get("bv", 0)).reshape(B, src.shape[1], KH, hd)
    q = constrain(q, "attn_bshd")

    if use_rope and kv_src is None:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k = apply_rope(k, q_pos, cfg.rope_theta)

    scale = cfg.attn_scale_override or None
    cap = cfg.attn_logit_softcap

    if kv_src is not None:
        # cross-attn: full visibility over context
        kv_pos = jnp.zeros((B, src.shape[1]), jnp.int32)
        o = attention_core(q, k, v, jnp.ones_like(q_pos), kv_pos,
                           causal=False, window=None, scale=scale, softcap=cap)
        new_cache = None
    elif cache is None:
        kv_pos = q_pos
        o = attention_core(q, k, v, q_pos, kv_pos, causal=causal,
                           window=window, scale=scale, softcap=cap)
        new_cache = None
    else:
        # decode / prefill-into-cache: write k,v at pos % W (ring buffer)
        W = cache["k"].shape[1]
        slots = q_pos % W                                   # (B, Sq)
        bidx = jnp.arange(B)[:, None]
        quantized = cache["k"].dtype == jnp.int8
        if quantized:
            kq, ks_ = _quantize_kv(k)
            vq, vs_ = _quantize_kv(v)
            ck = cache["k"].at[bidx, slots].set(kq)
            cv = cache["v"].at[bidx, slots].set(vq)
            cks = cache["k_scale"].at[bidx, slots].set(ks_)
            cvs = cache["v_scale"].at[bidx, slots].set(vs_)
        else:
            ck = cache["k"].at[bidx, slots].set(k.astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, slots].set(v.astype(cache["v"].dtype))
        # keep the updated buffers in the cache's own layout so GSPMD never
        # reshards (replicates!) the multi-GB cache around the attention dot
        ck = constrain(ck, "cache_kv")
        cv = constrain(cv, "cache_kv")
        cpos = cache["pos"].at[bidx, slots].set(q_pos)
        if quantized:
            k_use = _dequantize_kv(ck, cks, k.dtype)
            v_use = _dequantize_kv(cv, cvs, v.dtype)
        else:
            k_use, v_use = ck, cv
        o = attention_core(q, k_use, v_use, q_pos, cpos, causal=causal,
                           window=window, scale=scale, softcap=cap)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        if quantized:
            new_cache["k_scale"] = cks
            new_cache["v_scale"] = cvs

    o = o.reshape(B, Sq, H * hd) @ p["wo"]
    return o, new_cache


def make_cache(cfg, B, W, dtype=jnp.bfloat16, n_layers=None):
    """Empty ring-buffer cache for `n_layers` stacked layers.

    dtype=jnp.int8 selects the quantized cache (§Perf iteration 7):
    per-(slot, head) symmetric int8 with f32 scales — 2x less HBM at rest
    than bf16, dequantized on read.
    """
    KH, hd = cfg.n_kv_heads, cfg.head_dim_
    L = n_layers if n_layers is not None else cfg.n_layers
    shp = (L, B, W, KH, hd) if L else (B, W, KH, hd)
    pshp = shp[:-2]
    c = {
        "k": jnp.zeros(shp, dtype),
        "v": jnp.zeros(shp, dtype),
        "pos": jnp.full(pshp, -1, jnp.int32),
    }
    if dtype == jnp.int8:
        c["k_scale"] = jnp.zeros(shp[:-1], jnp.float32)
        c["v_scale"] = jnp.zeros(shp[:-1], jnp.float32)
    return c


def _quantize_kv(x):
    """x: (..., hd) -> (int8 values, (...,) f32 scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# --------------------------------------------------------------------------
# MLP (gated + plain)
# --------------------------------------------------------------------------

def init_mlp(cfg, key, dtype=jnp.float32, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_up": fan_in_init(ks[0], (d, f), dtype),
         "w_down": fan_in_init(ks[1], (f, d), dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = fan_in_init(ks[2], (d, f), dtype)
    return p


def mlp_block(cfg, p, x):
    a = act_fn(cfg.act)
    h = x @ p["w_up"]
    if "w_gate" in p:
        h = a(x @ p["w_gate"]) * h
    else:
        h = a(h)
    h = constrain(h, "tokens_bsf")
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# Mixture of Experts — sort-based capacity dispatch (no (T,E,C) one-hots)
# --------------------------------------------------------------------------

def init_moe(cfg, key, dtype=jnp.float32):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": fan_in_init(ks[0], (d, E), jnp.float32),  # router in f32
        "w_up": normal_init(ks[1], (E, d, f), 1 / math.sqrt(d), dtype),
        "w_down": normal_init(ks[2], (E, f, d), 1 / math.sqrt(f), dtype),
    }
    if cfg.gated_mlp:
        p["w_gate"] = normal_init(ks[3], (E, d, f), 1 / math.sqrt(d), dtype)
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], dtype,
                               d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def moe_block(cfg, p, x):
    """x: (B, S, d) -> (out, aux_loss).

    Sort-based dispatch: tokens are argsorted by expert id and scattered
    into an (E, C, d) capacity buffer — memory O(E*C*d), not O(T*E*C).
    Overflowing tokens are dropped (standard capacity-factor routing);
    their output is the shared-expert/zero contribution.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.n_experts_active
    T = B * S
    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ p["router"]            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)                 # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style)
    me = probs.mean(axis=0)                                  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce) * cfg.router_aux_loss_coef

    C = max(int(T * k / E * cfg.moe_capacity_factor), 4)
    C = min(C, T)

    flat_e = idx.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_e)                              # stable
    se = flat_e[order]                                       # sorted expert ids
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                     # (E,)
    pos_in_e = jnp.arange(T * k) - starts[se]                # position in expert
    tok = order // k                                         # source token
    valid = pos_in_e < C
    # scatter into capacity buffer; invalid -> dropped via index clamp+where
    slot_e = jnp.where(valid, se, 0)
    slot_c = jnp.where(valid, pos_in_e, C)                   # C = OOB -> dropped
    buf = jnp.zeros((E, C + 1, d), x.dtype)
    buf = buf.at[slot_e, slot_c].set(xf[tok])
    buf = buf[:, :C]
    buf = constrain(buf, "moe_ecd")

    a = act_fn(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    if "w_gate" in p:
        h = a(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * h
    else:
        h = a(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])     # (E, C, d)
    out_buf = jnp.concatenate([out_buf, jnp.zeros((E, 1, d), out_buf.dtype)],
                              axis=1)                        # OOB slot reads 0

    gathered = out_buf[slot_e, slot_c]                       # (T*k, d) sorted order
    unsorted = jnp.zeros((T * k, d), x.dtype).at[order].set(gathered)
    per_tok = unsorted.reshape(T, k, d)
    y = jnp.einsum("tkd,tk->td", per_tok, gate_vals.astype(x.dtype))

    if "shared" in p:
        y = y + mlp_block(cfg, p["shared"], xf)
    return y.reshape(B, S, d), aux


def _moe_dispatch_local(cfg, xf, logits, C):
    """Local sort+scatter dispatch. xf: (T, d); logits (T, E) f32.
    Returns (buf (E, C+1, d), slot_e, slot_c, order, gate_vals, aux_parts)."""
    E, k = cfg.n_experts, cfg.n_experts_active
    T = xf.shape[0]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    me = probs.mean(axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e)
    se = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * k) - starts[se]
    tok = order // k
    valid = pos_in_e < C
    slot_e = jnp.where(valid, se, 0)
    slot_c = jnp.where(valid, pos_in_e, C)
    buf = jnp.zeros((E, C + 1, xf.shape[1]), xf.dtype)
    buf = buf.at[slot_e, slot_c].set(xf[tok])
    return buf, slot_e, slot_c, order, gate_vals, (me, ce)


def moe_block_ep(cfg, p, x):
    """Expert-parallel MoE: shard_map + all_to_all over the `model` axis.

    §Perf iteration 2 (EXPERIMENTS.md): the scatter-based moe_block uses
    GLOBAL token indices (argsort over the full batch), which GSPMD can
    only partition by replicating the token buffers — measured 5.1e11
    collective bytes/device on kimi-k2 prefill_32k. Here routing is LOCAL
    to each (pod,data) shard: tokens are bucketed per destination expert
    shard and exchanged with two all_to_alls over `model`; the only other
    collective left is the FSDP weight all-gather.

    Falls back to moe_block when no model-parallel mesh is ambient.
    """
    mesh = compat.get_abstract_mesh()
    if (mesh is None or mesh.empty or "model" not in mesh.axis_names
            or mesh.shape["model"] == 1):
        return moe_block(cfg, p, x)
    M = mesh.shape["model"]
    E, k = cfg.n_experts, cfg.n_experts_active
    if E % M:
        return moe_block(cfg, p, x)
    E_loc = E // M
    bax = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    from jax.sharding import PartitionSpec as P
    B = x.shape[0]
    n_b = 1
    for a in bax:
        n_b *= mesh.shape[a]
    xspec = P(bax, None, None) if (bax and B % n_b == 0) else P(None, None, None)
    a = act_fn(cfg.act)

    def local_fn(xl, router, w_up, w_gate, w_down):
        Bl, S, d = xl.shape
        T = Bl * S
        xf = xl.reshape(T, d)
        logits = xf.astype(jnp.float32) @ router
        C = max(int(T * k / E * cfg.moe_capacity_factor), 4)
        C = min(C, T)
        buf, slot_e, slot_c, order, gate_vals, (me, ce) = \
            _moe_dispatch_local(cfg, xf, logits, C)
        send = buf[:, :C].reshape(M, E_loc, C, d)
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0)
        toks = recv.transpose(1, 0, 2, 3).reshape(E_loc, M * C, d)
        h = jnp.einsum("ecd,edf->ecf", toks, w_up)
        if w_gate is not None:
            h = a(jnp.einsum("ecd,edf->ecf", toks, w_gate)) * h
        else:
            h = a(h)
        out = jnp.einsum("ecf,efd->ecd", h, w_down)          # (E_loc, M*C, d)
        out = out.reshape(E_loc, M, C, d).transpose(1, 0, 2, 3)
        back = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0)
        out_buf = back.reshape(E, C, d)
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((E, 1, d), out_buf.dtype)], axis=1)
        gathered = out_buf[slot_e, slot_c]
        unsorted = jnp.zeros((T * k, d), xl.dtype).at[order].set(gathered)
        y = jnp.einsum("tkd,tk->td", unsorted.reshape(T, k, d),
                       gate_vals.astype(xl.dtype))
        # aux load-balance: average the per-shard statistics over cohorts
        if bax:
            me = jax.lax.pmean(me, bax)
            ce = jax.lax.pmean(ce, bax)
        aux = E * jnp.sum(me * ce) * cfg.router_aux_loss_coef
        return y.reshape(Bl, S, d), aux

    wg = p.get("w_gate")
    in_specs = (xspec, P(), P("model", None, None),
                P("model", None, None) if wg is not None else P(),
                P("model", None, None))
    y, aux = compat.shard_map(local_fn, mesh=mesh,
                              in_specs=in_specs,
                              out_specs=(xspec, P()),
                              check=False)(
        x, p["router"], p["w_up"], wg, p["w_down"])
    if "shared" in p:
        y = y + mlp_block(cfg, p["shared"], x.reshape(-1, x.shape[-1])
                          ).reshape(x.shape)
    return y, aux


def moe_apply(cfg, p, x):
    """Dispatch between MoE implementations per cfg.moe_impl."""
    impl = getattr(cfg, "moe_impl", "scatter")
    if impl == "ep":
        return moe_block_ep(cfg, p, x)
    if impl == "auto":
        mesh = compat.get_abstract_mesh()
        if (mesh is not None and not mesh.empty
                and "model" in mesh.axis_names and mesh.shape["model"] > 1
                and cfg.n_experts % mesh.shape["model"] == 0):
            # EP pays off only when each expert sees >= ~1 token per data
            # shard; at decode-sized token counts the capacity padding and
            # a2a latency dominate (§Perf iteration 6: kimi decode_32k
            # regressed 7x in flops under unconditional EP).
            n_b = 1
            for a in mesh.axis_names:
                if a in ("pod", "data"):
                    n_b *= mesh.shape[a]
            t_loc = (x.shape[0] * x.shape[1]) / max(n_b, 1)
            if t_loc * cfg.n_experts_active / cfg.n_experts >= 1.0:
                return moe_block_ep(cfg, p, x)
    return moe_block(cfg, p, x)


def moe_block_dense_ref(cfg, p, x):
    """Reference dense-gather MoE (every token through every expert);
    numerically exact routing used to validate moe_block in tests."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, cfg.n_experts_active)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    a = act_fn(cfg.act)
    h = jnp.einsum("td,edf->tef", xf, p["w_up"])
    if "w_gate" in p:
        h = a(jnp.einsum("td,edf->tef", xf, p["w_gate"])) * h
    else:
        h = a(h)
    all_out = jnp.einsum("tef,efd->ted", h, p["w_down"])     # (T, E, d)
    sel = jnp.take_along_axis(all_out, idx[..., None], axis=1)  # (T, k, d)
    y = jnp.einsum("tkd,tk->td", sel, gate_vals.astype(x.dtype))
    if "shared" in p:
        y = y + mlp_block(cfg, p["shared"], xf)
    return y.reshape(B, S, d)


# --------------------------------------------------------------------------
# RWKV6 (Finch) time-mix — chunked data-dependent-decay recurrence
# --------------------------------------------------------------------------

RWKV_CHUNK = 16          # small chunk keeps exp(cum_i - cum_j) exact & safe
RWKV_DECAY_FLOOR = -4.0  # clamp per-step log-decay (deviation noted in DESIGN)


def init_rwkv_tmix(cfg, key, dtype=jnp.float32):
    d = cfg.d_model
    H = d // cfg.rwkv_head_dim
    ks = jax.random.split(key, 8)
    return {
        "mu": normal_init(ks[0], (5, d), 0.1, dtype),        # shift-mix for r,k,v,g,w
        "wr": fan_in_init(ks[1], (d, d), dtype),
        "wk": fan_in_init(ks[2], (d, d), dtype),
        "wv": fan_in_init(ks[3], (d, d), dtype),
        "wg": fan_in_init(ks[4], (d, d), dtype),
        "w0": normal_init(ks[5], (d,), 0.5, jnp.float32) - 2.0,  # base decay
        "w_lora_a": fan_in_init(ks[6], (d, 64), dtype),
        "w_lora_b": normal_init(ks[7], (64, d), 0.01, jnp.float32),
        "u": normal_init(jax.random.fold_in(key, 9), (d,), 0.1, jnp.float32),
        "wo": fan_in_init(jax.random.fold_in(key, 10), (d, d), dtype),
    }


def _rwkv_project(cfg, p, x, x_prev):
    """Token-shift mixing + projections. x: (B,S,d); x_prev: previous token
    of x (B,S,d) (shifted, first position given by carry)."""
    mu = p["mu"].astype(jnp.float32)[:, None, None, :]       # (5,1,1,d)
    xs = x.astype(jnp.float32)
    xp = x_prev.astype(jnp.float32)
    mixed = xs + (xp - xs) * mu                              # (5,B,S,d)
    xr, xk, xv, xg, xw = mixed
    r = (xr.astype(x.dtype) @ p["wr"])
    k = (xk.astype(x.dtype) @ p["wk"])
    v = (xv.astype(x.dtype) @ p["wv"])
    g = jax.nn.silu(xg.astype(x.dtype) @ p["wg"])
    lw = p["w0"] + (jnp.tanh(xw.astype(x.dtype) @ p["w_lora_a"]).astype(jnp.float32)
                    @ p["w_lora_b"])
    logw = -jnp.exp(lw)                                      # log decay < 0
    logw = jnp.clip(logw, RWKV_DECAY_FLOOR, -1e-4)
    return r, k, v, g, logw


def rwkv_tmix_chunked(cfg, p, x, state=None, x_last=None):
    """RWKV6 time-mix over a full sequence.

    x: (B, S, d). state: (B, H, D, D) carry (k-dim, v-dim) or None.
    Returns (out (B,S,d), new_state, last_x (B,d)).
    Recurrence per head: S_t = diag(w_t) S_{t-1} + k_t v_t^T,
                         o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T).
    Chunked: within a chunk of length C the pairwise decay
    exp(cum_{i-1} - cum_j) (i>j) is computed AFTER the subtraction, so it
    is always <= 1 — no overflow, no rescaling pass needed.
    """
    B, S, d = x.shape
    D = cfg.rwkv_head_dim
    H = d // D
    C = min(RWKV_CHUNK, S)
    if S % C != 0:
        # split into a chunk-aligned head and a tail, carrying state across
        S_main = (S // C) * C
        o1, st1, xl1 = rwkv_tmix_chunked(cfg, p, x[:, :S_main], state, x_last)
        o2, st2, xl2 = rwkv_tmix_chunked(cfg, p, x[:, S_main:], st1, xl1)
        return jnp.concatenate([o1, o2], axis=1), st2, xl2
    x_prev = jnp.concatenate(
        [(x_last[:, None] if x_last is not None else jnp.zeros_like(x[:, :1])),
         x[:, :-1]], axis=1)
    r, k, v, g, logw = _rwkv_project(cfg, p, x, x_prev)
    u = p["u"].astype(jnp.float32).reshape(H, D)

    def hsplit(t):  # (B,S,d)->(B,nc,C,H,D)
        return t.reshape(B, S // C, C, H, D)

    rs, ks, vs = hsplit(r.astype(jnp.float32)), hsplit(k.astype(jnp.float32)), \
        hsplit(v.astype(jnp.float32))
    lws = hsplit(logw)

    if state is None:
        state = jnp.zeros((B, H, D, D), jnp.float32)

    def chunk_body(S0, xs):
        rc, kc, vc, lwc = xs                                 # (B,C,H,D)
        cum = jnp.cumsum(lwc, axis=1)                        # (B,C,H,D)
        cum_prev = cum - lwc                                 # cum_{i-1}
        # carry-in: o_i += (r_i * exp(cum_{i-1}))^T S0
        a = rc * jnp.exp(cum_prev)
        o = jnp.einsum("bchd,bhde->bche", a, S0)
        # intra-chunk: scores_ij = sum_d r_id k_jd exp(cum_{i-1,d} - cum_{j,d})
        dec = jnp.exp(cum_prev[:, :, None] - cum[:, None, :, :])  # (B,C,C,H,D)
        tri = jnp.tril(jnp.ones((C, C), bool), k=-1)[None, :, :, None, None]
        scores = jnp.sum(rc[:, :, None] * kc[:, None, :] * jnp.where(tri, dec, 0.0),
                         axis=-1)                            # (B,C,C,H)
        o = o + jnp.einsum("bcjh,bjhe->bche", scores, vc)
        # current-token bonus
        bonus = jnp.sum(rc * u[None, None] * kc, axis=-1)    # (B,C,H)
        o = o + bonus[..., None] * vc
        # state update: S_end = diag(prod w) S0 + sum_j diag(exp(cum_C - cum_j)) k_j v_j^T
        total = cum[:, -1]                                   # (B,H,D)
        kdec = kc * jnp.exp(total[:, None] - cum)            # (B,C,H,D)
        S_new = S0 * jnp.exp(total)[..., None] + jnp.einsum("bchd,bche->bhde", kdec, vc)
        return S_new, o

    xs = tuple(t.transpose(1, 0, 2, 3, 4) for t in (rs, ks, vs, lws))
    state_f, outs = jax.lax.scan(chunk_body, state, xs)
    o = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H * D)
    o = (o.astype(x.dtype) * g) @ p["wo"]
    return o, state_f, x[:, -1]


def rwkv_tmix_step(cfg, p, x, state, x_last):
    """Single-token decode step. x: (B,1,d). state: (B,H,D,D)."""
    B, _, d = x.shape
    D = cfg.rwkv_head_dim
    H = d // D
    r, k, v, g, logw = _rwkv_project(cfg, p, x, x_last[:, None])
    rh = r.astype(jnp.float32).reshape(B, H, D)
    kh = k.astype(jnp.float32).reshape(B, H, D)
    vh = v.astype(jnp.float32).reshape(B, H, D)
    w = jnp.exp(logw.reshape(B, H, D))
    u = p["u"].astype(jnp.float32).reshape(H, D)
    kv = kh[..., :, None] * vh[..., None, :]                 # (B,H,D,D)
    o = jnp.einsum("bhd,bhde->bhe", rh, state + u[None, :, :, None] * kv)
    state = state * w[..., None] + kv
    o = o.reshape(B, 1, H * D).astype(x.dtype) * g
    return o @ p["wo"], state, x[:, -1]


def init_rwkv_cmix(cfg, key, dtype=jnp.float32):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "mu": normal_init(ks[0], (2, d), 0.1, dtype),
        "w_up": fan_in_init(ks[1], (d, cfg.d_ff), dtype),
        "w_down": fan_in_init(ks[2], (cfg.d_ff, d), dtype),
    }


def rwkv_cmix(cfg, p, x, x_last=None):
    """Channel-mix (square-relu FFN with token shift)."""
    x_prev = jnp.concatenate(
        [(x_last[:, None] if x_last is not None else jnp.zeros_like(x[:, :1])),
         x[:, :-1]], axis=1)
    mu = p["mu"].astype(jnp.float32)[:, None, None, :]
    xs, xp = x.astype(jnp.float32), x_prev.astype(jnp.float32)
    mixed = xs + (xp - xs) * mu
    xk, _ = mixed
    h = jnp.square(jax.nn.relu(xk.astype(x.dtype) @ p["w_up"]))
    return h @ p["w_down"], x[:, -1]


# --------------------------------------------------------------------------
# Selective SSM (Mamba-style, for Hymba's parallel branch)
# --------------------------------------------------------------------------

SSM_CHUNK = 128


def init_ssm(cfg, key, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    st = cfg.ssm_state
    ks = jax.random.split(key, 7)
    return {
        "w_in": fan_in_init(ks[0], (d, 2 * di), dtype),
        "conv": normal_init(ks[1], (4, di), 0.5, dtype),      # depthwise width-4
        "w_dt": fan_in_init(ks[2], (di, di), dtype),
        "b_dt": jnp.full((di,), -3.0, jnp.float32),           # softplus(-3)≈0.05
        "w_B": fan_in_init(ks[3], (di, st), dtype),
        "w_C": fan_in_init(ks[4], (di, st), dtype),
        "A_log": jnp.log(jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "w_out": fan_in_init(ks[5], (di, d), dtype),
    }


def _ssm_conv(p, x, conv_state=None):
    """Causal depthwise conv, width 4. x: (B,S,di)."""
    w = p["conv"].astype(jnp.float32)                        # (4, di)
    pad = conv_state if conv_state is not None else jnp.zeros(
        (x.shape[0], 3, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1).astype(jnp.float32)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(4))
    return y.astype(x.dtype), xp[:, -3:].astype(x.dtype)


def ssm_block(cfg, p, x, state=None, conv_state=None):
    """Selective SSM. x: (B,S,d) -> (out, (h_state, conv_state)).

    h_t = exp(dt_t*A) h_{t-1} + dt_t * (x_t ⊗ B_t);  y_t = h_t · C_t + D*x_t
    Chunked lax.scan with an inner associative scan (chunk SSM_CHUNK).
    """
    B, S, d = x.shape
    di, st = cfg.ssm_expand * d, cfg.ssm_state
    C0 = min(SSM_CHUNK, S)
    if S % C0 != 0:
        S_main = (S // C0) * C0
        o1, (h1, c1) = ssm_block(cfg, p, x[:, :S_main], state, conv_state)
        o2, (h2, c2) = ssm_block(cfg, p, x[:, S_main:], h1, c1)
        return jnp.concatenate([o1, o2], axis=1), (h2, c2)
    xz = x @ p["w_in"]
    x1, z = jnp.split(xz, 2, axis=-1)
    x1, conv_state = _ssm_conv(p, x1, conv_state)
    x1 = jax.nn.silu(x1)
    dt = jax.nn.softplus(x1 @ p["w_dt"] + p["b_dt"]).astype(jnp.float32)  # (B,S,di)
    Bm = (x1 @ p["w_B"]).astype(jnp.float32)                 # (B,S,st)
    Cm = (x1 @ p["w_C"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                 # (di, st)
    if state is None:
        state = jnp.zeros((B, di, st), jnp.float32)

    C = min(SSM_CHUNK, S)
    assert S % C == 0
    # §Perf iteration 4: the (B,S,di,st) decay/input tensors a,b are built
    # PER CHUNK inside the scan body (from (B,C,di)/(B,C,st) slices) so
    # they fuse into the chunk computation instead of round-tripping the
    # full-sequence 4-D tensors through HBM.
    nchunks = S // C

    def to_chunks(t):
        return t.reshape(B, nchunks, C, t.shape[-1]).transpose(1, 0, 2, 3)

    dtc = to_chunks(dt)
    x1c = to_chunks(x1.astype(jnp.float32))
    Bmc = to_chunks(Bm)
    Cmc = to_chunks(Cm)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_body(h0, xs):
        dti, x1i, Bmi, Cmi = xs                              # (B,C,di)/(B,C,st)
        aci = jnp.exp(dti[..., None] * A[None, None])        # (B,C,di,st)
        bci = (dti * x1i)[..., None] * Bmi[:, :, None, :]
        A_, B_ = jax.lax.associative_scan(combine, (aci, bci), axis=1)
        h = A_ * h0[:, None] + B_                            # (B,C,di,st)
        yi = jnp.einsum("bcdn,bcn->bcd", h, Cmi)
        return h[:, -1], yi

    h_last, ys = jax.lax.scan(chunk_body, state, (dtc, x1c, Bmc, Cmc),
                              unroll=scan_ctx.resolve("time", nchunks))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    y = y + p["D"] * x1.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["w_out"], (h_last, conv_state)


def ssm_step(cfg, p, x, state, conv_state):
    """Single-token decode step. x: (B,1,d)."""
    out, (h, cs) = ssm_block(cfg, p, x, state=state, conv_state=conv_state)
    return out, (h, cs)
