"""ResNet-18 (CIFAR variant) + 128-D projection head — the FLSimCo backbone.

Paper Sec 5.1: "improved ResNet-18 with a fixed dimension of 128-D".
CIFAR stem (3x3 conv stride 1, no max-pool), stages [2,2,2,2] at widths
[64,128,256,512], BatchNorm with running stats, global average pool, and a
SimCLR-style 2-layer MLP projector to 128-D (L2-normalized output).

Functional: ``init_resnet`` -> (params, state) where ``state`` holds BN
running statistics. ``resnet_apply(params, state, x, train)`` returns
(features_128, new_state). BN stats are part of the federated aggregation
payload (DESIGN.md deviation #3).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import normal_init

STAGES = (2, 2, 2, 2)
WIDTHS = (64, 128, 256, 512)


def _conv_init(key, kh, kw, cin, cout, dtype):
    fan_in = kh * kw * cin
    return normal_init(key, (kh, kw, cin, cout), math.sqrt(2.0 / fan_in), dtype)


def _init_bn(c):
    return ({"scale": jnp.ones((c,), jnp.float32),
             "bias": jnp.zeros((c,), jnp.float32)},
            {"mean": jnp.zeros((c,), jnp.float32),
             "var": jnp.ones((c,), jnp.float32)})


def init_resnet(cfg, key, dtype=jnp.float32):
    """Returns {"params": ..., "state": ...} pytree."""
    keys = iter(jax.random.split(key, 64))
    params: dict = {}
    state: dict = {}
    params["stem"] = _conv_init(next(keys), 3, 3, 3, WIDTHS[0], dtype)
    params["stem_bn"], state["stem_bn"] = _init_bn(WIDTHS[0])

    cin = WIDTHS[0]
    for si, (n_blocks, w) in enumerate(zip(STAGES, WIDTHS)):
        for bi in range(n_blocks):
            name = f"s{si}b{bi}"
            stride = 2 if (bi == 0 and si > 0) else 1
            blk: dict = {
                "conv1": _conv_init(next(keys), 3, 3, cin, w, dtype),
                "conv2": _conv_init(next(keys), 3, 3, w, w, dtype),
            }
            st: dict = {}
            blk["bn1"], st["bn1"] = _init_bn(w)
            blk["bn2"], st["bn2"] = _init_bn(w)
            if stride != 1 or cin != w:
                blk["proj"] = _conv_init(next(keys), 1, 1, cin, w, dtype)
                blk["proj_bn"], st["proj_bn"] = _init_bn(w)
            params[name] = blk
            state[name] = st
            cin = w

    # projector: 512 -> 512 -> 128 (SimCLR-style)
    params["proj1"] = normal_init(next(keys), (WIDTHS[-1], WIDTHS[-1]),
                                  1 / math.sqrt(WIDTHS[-1]), dtype)
    params["proj1_b"] = jnp.zeros((WIDTHS[-1],), dtype)
    params["proj2"] = normal_init(next(keys), (WIDTHS[-1], cfg.d_ff),
                                  1 / math.sqrt(WIDTHS[-1]), dtype)
    return {"params": params, "state": state}


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(p, s, x, train: bool, momentum=0.9):
    """BatchNorm over NHW. Returns (y, new_state)."""
    if train:
        xf = x.astype(jnp.float32)
        mean = xf.mean(axis=(0, 1, 2))
        var = xf.var(axis=(0, 1, 2))
        new_s = {"mean": momentum * s["mean"] + (1 - momentum) * mean,
                 "var": momentum * s["var"] + (1 - momentum) * var}
    else:
        mean, var = s["mean"], s["var"]
        new_s = s
    y = (x.astype(jnp.float32) - mean) * jax.lax.rsqrt(var + 1e-5)
    y = y * p["scale"] + p["bias"]
    return y.astype(x.dtype), new_s


def resnet_apply(tree, x, train: bool = True):
    """x: (B, 32, 32, 3) -> (z128 L2-normalized, h512 pre-projector, new_state)."""
    p, s = tree["params"], tree["state"]
    ns: dict = {}
    h = _conv(x, p["stem"])
    h, ns["stem_bn"] = _bn(p["stem_bn"], s["stem_bn"], h, train)
    h = jax.nn.relu(h)

    cin = WIDTHS[0]
    for si, (n_blocks, w) in enumerate(zip(STAGES, WIDTHS)):
        for bi in range(n_blocks):
            name = f"s{si}b{bi}"
            blk, bst = p[name], s[name]
            stride = 2 if (bi == 0 and si > 0) else 1
            nbs: dict = {}
            y = _conv(h, blk["conv1"], stride)
            y, nbs["bn1"] = _bn(blk["bn1"], bst["bn1"], y, train)
            y = jax.nn.relu(y)
            y = _conv(y, blk["conv2"])
            y, nbs["bn2"] = _bn(blk["bn2"], bst["bn2"], y, train)
            if "proj" in blk:
                sc = _conv(h, blk["proj"], stride)
                sc, nbs["proj_bn"] = _bn(blk["proj_bn"], bst["proj_bn"], sc, train)
            else:
                sc = h
            h = jax.nn.relu(y + sc)
            ns[name] = nbs
            cin = w

    h = h.mean(axis=(1, 2))                                   # (B, 512)
    z = jax.nn.relu(h @ p["proj1"] + p["proj1_b"])
    z = z @ p["proj2"]                                        # (B, 128)
    z = z.astype(jnp.float32)
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-8)
    return z, h, {"params": p, "state": ns}
