"""Scan-unroll context for cost calibration.

XLA's cost_analysis counts a while-loop body ONCE, not x trip-count, so
scan-over-layers / kv-chunk scans under-report FLOPs by the trip count.
The dry-run calibration lowers reduced-depth variants with scans UNROLLED
(so every body instance is visible to the analyzer) and extrapolates.

Default is unroll=1 (plain scan) everywhere; only dryrun's calibration
flips this, inside a context manager.
"""
from __future__ import annotations

import contextlib
import threading

_local = threading.local()

FULL = -1  # sentinel: unroll the whole scan


def get(kind: str) -> int:
    return getattr(_local, kind, 1)


def resolve(kind: str, length: int):
    u = get(kind)
    if u == FULL:
        return length
    return min(u, length) if u > 1 else 1


@contextlib.contextmanager
def unrolled(layers: int = 1, kv: int = 1, time: int = 1):
    prev = (get("layers"), get("kv"), get("time"))
    _local.layers, _local.kv, _local.time = layers, kv, time
    try:
        yield
    finally:
        _local.layers, _local.kv, _local.time = prev
