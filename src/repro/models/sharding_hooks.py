"""Optional activation-sharding hooks.

Models stay pure-functional; the launcher installs a constraint function
(typically ``jax.lax.with_sharding_constraint`` bound to a mesh + logical
rules) so hot activations get explicit shardings during lowering. Default
is identity, so unit tests / CPU runs never touch device state.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

Array = "jax.Array"

_local = threading.local()


def _default(x, name: str):
    return x


def constrain(x, name: str):
    """Apply the installed sharding constraint for logical activation `name`.

    Names used by the model zoo:
      tokens_bsd   — (batch, seq, d_model)
      tokens_bsf   — (batch, seq, d_ff)   (MLP hidden)
      attn_bshd    — (batch, seq, heads, head_dim)
      moe_ecd      — (experts, capacity, d)
      logits_bsv   — (batch, seq, vocab)
      cache_blwh   — kv cache
    """
    fn = getattr(_local, "fn", None) or _default
    return fn(x, name)


@contextlib.contextmanager
def activation_sharding(fn: Callable):
    prev = getattr(_local, "fn", None)
    _local.fn = fn
    try:
        yield
    finally:
        _local.fn = prev
