"""Model assembly for the 10 assigned architecture families.

One init + one apply per family-block; ``init_params``/``forward`` dispatch
on ``cfg.family``. Repeated blocks are stacked along a leading layer axis
and executed with ``jax.lax.scan`` so the HLO stays O(1) in depth (95-100
layer archs compile as fast as 2-layer ones).

Modes:
  train   — full-sequence causal (or enc-dec) teacher forcing -> logits
  prefill — like train but also fills + returns the KV cache
  decode  — one new token against a ring-buffer KV cache

Cache layout (self-attention families): dict of stacked arrays with a
leading layer axis, built by ``init_cache``; ring-buffer semantics support
both full caches (W = seq_len) and sliding-window caches (W = window) for
the long_500k shape (``long_context=True``).
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import scan_ctx
from repro.models.sharding_hooks import constrain

Params = Any


# --------------------------------------------------------------------------
# block init / apply — shared decoder block (dense & moe & vlm-self)
# --------------------------------------------------------------------------

def _init_decoder_block(cfg, key, dtype, moe: bool = False):
    ks = jax.random.split(key, 2)
    p = {
        "ln1": L.init_norm(cfg, dtype=dtype),
        "attn": L.init_attention(cfg, ks[0], dtype),
        "ln2": L.init_norm(cfg, dtype=dtype),
    }
    if moe:
        p["moe"] = L.init_moe(cfg, ks[1], dtype)
    else:
        p["mlp"] = L.init_mlp(cfg, ks[1], dtype)
    if cfg.post_norm:
        p["ln1_post"] = L.init_norm(cfg, dtype=dtype)
        p["ln2_post"] = L.init_norm(cfg, dtype=dtype)
    return p


def _decoder_block(cfg, p, x, q_pos, *, window, cache=None):
    h, new_cache = L.attention_block(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x),
                                     q_pos, window=window, cache=cache)
    if cfg.post_norm:
        h = L.apply_norm(cfg, p["ln1_post"], h)
    x = x + h
    hin = L.apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        h, aux = L.moe_apply(cfg, p["moe"], hin)
    else:
        h, aux = L.mlp_block(cfg, p["mlp"], hin), 0.0
    if cfg.post_norm:
        h = L.apply_norm(cfg, p["ln2_post"], h)
    x = x + h
    x = constrain(x, "tokens_bsd")
    return x, new_cache, aux


def _init_cross_block(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_norm(cfg, dtype=dtype),
        "xattn": L.init_attention(cfg, ks[0], dtype, cross=True),
        "ln2": L.init_norm(cfg, dtype=dtype),
        "mlp": L.init_mlp(cfg, ks[1], dtype),
        "gate_attn": jnp.zeros((), jnp.float32),
        "gate_mlp": jnp.zeros((), jnp.float32),
    }


def _cross_block(cfg, p, x, q_pos, ctx):
    """Gated cross-attention block (llama-3.2-vision / enc-dec decoder)."""
    h, _ = L.attention_block(cfg, p["xattn"], L.apply_norm(cfg, p["ln1"], x),
                             q_pos, kv_src=ctx, use_rope=False)
    x = x + jnp.tanh(p["gate_attn"]).astype(x.dtype) * h
    h = L.mlp_block(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    x = x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * h
    return x


def _init_encoder_block(cfg, key, dtype):
    return _init_decoder_block(cfg, key, dtype, moe=False)


def _encoder_block(cfg, p, x, pos):
    h, _ = L.attention_block(cfg, p["attn"], L.apply_norm(cfg, p["ln1"], x),
                             pos, causal=False)
    x = x + h
    x = x + L.mlp_block(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    return x


def _init_rwkv_block(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_layernorm(cfg.d_model, dtype),
        "tmix": L.init_rwkv_tmix(cfg, ks[0], dtype),
        "ln2": L.init_layernorm(cfg.d_model, dtype),
        "cmix": L.init_rwkv_cmix(cfg, ks[1], dtype),
    }


def _init_hymba_block(cfg, key, dtype):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.init_norm(cfg, dtype=dtype),
        "attn": L.init_attention(cfg, ks[0], dtype),
        "ssm": L.init_ssm(cfg, ks[1], dtype),
        "norm_attn": L.init_rmsnorm(cfg.d_model, dtype),
        "norm_ssm": L.init_rmsnorm(cfg.d_model, dtype),
        "ln2": L.init_norm(cfg, dtype=dtype),
        "mlp": L.init_mlp(cfg, ks[2], dtype),
    }


def _hymba_block(cfg, p, x, q_pos, *, window, cache=None, ssm_state=None,
                 conv_state=None):
    """Parallel attention + SSM heads, mean-fused (Hymba)."""
    xn = L.apply_norm(cfg, p["ln1"], x)
    ha, new_cache = L.attention_block(cfg, p["attn"], xn, q_pos,
                                      window=window, cache=cache)
    hs, (new_ssm, new_conv) = L.ssm_block(cfg, p["ssm"], xn,
                                          state=ssm_state, conv_state=conv_state)
    h = 0.5 * (L.rmsnorm(p["norm_attn"], ha) + L.rmsnorm(p["norm_ssm"], hs))
    x = x + h
    x = x + L.mlp_block(cfg, p["mlp"], L.apply_norm(cfg, p["ln2"], x))
    x = constrain(x, "tokens_bsd")
    return x, new_cache, new_ssm, new_conv


# --------------------------------------------------------------------------
# stacked init
# --------------------------------------------------------------------------

def _stack(fn, key, n):
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg, key, dtype=jnp.float32) -> Params:
    """Build the full parameter pytree for any supported family."""
    if cfg.family == "resnet":
        from repro.models.resnet import init_resnet
        return init_resnet(cfg, key, dtype)

    kE, kB, kO, kX = jax.random.split(key, 4)
    V, d = cfg.padded_vocab, cfg.d_model
    p: dict = {
        "embed": L.normal_init(kE, (V, d), 0.02, dtype),
        "final_norm": L.init_norm(cfg, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = L.normal_init(kO, (d, V), 1 / math.sqrt(d), dtype)

    fam = cfg.family
    if fam == "dense":
        p["blocks"] = _stack(lambda k: _init_decoder_block(cfg, k, dtype),
                             kB, cfg.n_layers)
    elif fam == "moe":
        n_dense = cfg.moe_first_dense_layers
        if n_dense:
            kD, kB = jax.random.split(kB)
            p["dense_blocks"] = _stack(
                lambda k: _init_decoder_block(cfg, k, dtype, moe=False), kD, n_dense)
        p["blocks"] = _stack(lambda k: _init_decoder_block(cfg, k, dtype, moe=True),
                             kB, cfg.n_layers - n_dense)
    elif fam == "ssm":
        p["blocks"] = _stack(lambda k: _init_rwkv_block(cfg, k, dtype),
                             kB, cfg.n_layers)
    elif fam == "hybrid":
        p["blocks"] = _stack(lambda k: _init_hymba_block(cfg, k, dtype),
                             kB, cfg.n_layers)
    elif fam == "vlm":
        per = cfg.cross_attn_period
        n_super = cfg.n_layers // per
        n_self = per - 1
        p["blocks"] = _stack(
            lambda k: jax.vmap(lambda kk: _init_decoder_block(cfg, kk, dtype))(
                jax.random.split(k, n_self)), kB, n_super)
        p["cross_blocks"] = _stack(lambda k: _init_cross_block(cfg, k, dtype),
                                   kX, n_super)
        p["vision_proj"] = L.fan_in_init(jax.random.fold_in(kX, 1),
                                         (cfg.d_vision, d), dtype)
    elif fam == "audio":
        p["enc_blocks"] = _stack(lambda k: _init_encoder_block(cfg, k, dtype),
                                 kX, cfg.n_encoder_layers)
        p["blocks"] = _stack(lambda k: _init_decoder_block(cfg, k, dtype),
                             kB, cfg.n_layers)
        p["cross_blocks"] = _stack(
            lambda k: _init_cross_block(cfg, k, dtype),
            jax.random.fold_in(kX, 1), cfg.n_layers)
        p["audio_adapter"] = L.fan_in_init(jax.random.fold_in(kX, 2),
                                           (cfg.d_audio, d), dtype)
        p["enc_norm"] = L.init_norm(cfg, dtype=dtype)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# --------------------------------------------------------------------------
# per-layer attention windows / cache geometry
# --------------------------------------------------------------------------

def layer_windows(cfg, n_layers: int, long_context: bool):
    """(n_layers,) int32 per-layer attention window (BIG_WINDOW = none)."""
    big = L.BIG_WINDOW
    glob = cfg.long_context_window if long_context else big
    if cfg.local_global_period:
        idx = jnp.arange(n_layers)
        local = (idx % cfg.local_global_period) == 0
        return jnp.where(local, cfg.sliding_window, glob).astype(jnp.int32)
    w = cfg.sliding_window if cfg.sliding_window else glob
    return jnp.full((n_layers,), w, jnp.int32)


def cache_width(cfg, seq_len: int, long_context: bool) -> int:
    """Ring-buffer width for decode caches."""
    if long_context:
        if cfg.long_context_mode == "native" and cfg.sliding_window:
            return min(seq_len, cfg.sliding_window)   # hymba attn branch
        return min(seq_len, cfg.long_context_window)
    if cfg.sliding_window and not cfg.local_global_period:
        return min(seq_len, cfg.sliding_window)
    return seq_len


# --------------------------------------------------------------------------
# caches
# --------------------------------------------------------------------------

def init_cache(cfg, batch: int, seq_len: int, dtype=jnp.bfloat16, *,
               long_context: bool = False, ctx_len: int = 0) -> dict:
    """Empty decode cache. seq_len = max absolute position to be served."""
    fam = cfg.family
    W = cache_width(cfg, seq_len, long_context)
    d = cfg.d_model
    if fam == "ssm":
        H = d // cfg.rwkv_head_dim
        D = cfg.rwkv_head_dim
        return {
            "state": jnp.zeros((cfg.n_layers, batch, H, D, D), jnp.float32),
            "x_last_t": jnp.zeros((cfg.n_layers, batch, d), dtype),
            "x_last_c": jnp.zeros((cfg.n_layers, batch, d), dtype),
        }
    if fam == "hybrid":
        di = cfg.ssm_expand * d
        return {
            "kv": L.make_cache(cfg, batch, W, dtype, n_layers=cfg.n_layers),
            "ssm": jnp.zeros((cfg.n_layers, batch, di, cfg.ssm_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch, 3, di), dtype),
        }
    if fam == "vlm":
        per = cfg.cross_attn_period
        n_super = cfg.n_layers // per
        return {
            "kv": L.make_cache(cfg, batch, W, dtype,
                               n_layers=n_super * (per - 1)),
            "ctx": jnp.zeros((batch, cfg.n_vision_tokens, d), dtype),
        }
    if fam == "audio":
        return {
            "kv": L.make_cache(cfg, batch, W, dtype, n_layers=cfg.n_layers),
            "ctx": jnp.zeros((batch, ctx_len, d), dtype),
        }
    n_dense = cfg.moe_first_dense_layers if fam == "moe" else 0
    c = {"kv": L.make_cache(cfg, batch, W, dtype, n_layers=cfg.n_layers - n_dense)}
    if n_dense:
        c["kv_dense"] = L.make_cache(cfg, batch, W, dtype, n_layers=n_dense)
    return c


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _embed(cfg, p, tokens):
    x = p["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _head(cfg, p, x):
    x = L.apply_norm(cfg, p["final_norm"], x)
    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = cfg.final_logit_softcap * jnp.tanh(logits / cfg.final_logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(mask, logits, L.NEG_INF)
    return constrain(logits, "logits_bsv")


def _scan_blocks(body, carry, *xs):
    def f(c, inp):
        return body(c, *inp)

    length = jax.tree.leaves(xs[0])[0].shape[0]
    return jax.lax.scan(f, carry, xs,
                        unroll=scan_ctx.resolve("layers", length))


def _forward_hidden(cfg, p, tokens, *, mode, cache, positions, aux_inputs,
                    long_context):
    """Backbone: embeddings -> blocks. Returns (hidden, new_cache, aux)."""
    B, S = tokens.shape
    fam = cfg.family
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    elif positions.ndim == 1:
        positions = positions[:, None] + jnp.arange(S, dtype=jnp.int32)[None]

    x = _embed(cfg, p, tokens)
    aux_total = jnp.zeros((), jnp.float32)

    if fam in ("dense", "moe"):
        n_dense = cfg.moe_first_dense_layers if fam == "moe" else 0

        def body(carry, blk, win, kv=None):
            xx, aux = carry
            xx, new_kv, a = _decoder_block(cfg, blk, xx, positions,
                                           window=win, cache=kv)
            return (xx, aux + a), new_kv

        new_cache = {} if cache is not None else None
        if n_dense:
            dwins = layer_windows(cfg, n_dense, long_context)
            if cache is not None:
                (x, aux_total), ndkv = _scan_blocks(
                    lambda c, blk, w, kv: body(c, blk, w, kv),
                    (x, aux_total), p["dense_blocks"], dwins, cache["kv_dense"])
                new_cache["kv_dense"] = ndkv
            else:
                (x, aux_total), _ = _scan_blocks(
                    lambda c, blk, w: body(c, blk, w),
                    (x, aux_total), p["dense_blocks"], dwins)
        wins = layer_windows(cfg, cfg.n_layers - n_dense, long_context)
        if cache is not None:
            (x, aux_total), nkv = _scan_blocks(
                lambda c, blk, w, kv: body(c, blk, w, kv),
                (x, aux_total), p["blocks"], wins, cache["kv"])
            new_cache["kv"] = nkv
        else:
            (x, aux_total), _ = _scan_blocks(
                lambda c, blk, w: body(c, blk, w),
                (x, aux_total), p["blocks"], wins)

    elif fam == "ssm":
        def body(xx, blk, st=None):
            xn = L.layernorm(blk["ln1"], xx)
            if mode == "decode":
                o, s_new, xl_t = L.rwkv_tmix_step(cfg, blk["tmix"], xn,
                                                  st["state"], st["x_last_t"])
            else:
                o, s_new, xl_t = L.rwkv_tmix_chunked(
                    cfg, blk["tmix"], xn,
                    state=st["state"] if st is not None else None,
                    x_last=st["x_last_t"] if st is not None else None)
            xx = xx + o
            xn2 = L.layernorm(blk["ln2"], xx)
            o2, xl_c = L.rwkv_cmix(cfg, blk["cmix"], xn2,
                                   x_last=st["x_last_c"] if st is not None else None)
            xx = xx + o2
            xx = constrain(xx, "tokens_bsd")
            return xx, {"state": s_new, "x_last_t": xl_t, "x_last_c": xl_c}

        if cache is not None:
            x, new_cache = _scan_blocks(lambda c, blk, st: body(c, blk, st),
                                        x, p["blocks"], cache)
        else:
            x, states = _scan_blocks(lambda c, blk: body(c, blk), x, p["blocks"])
            new_cache = states if mode == "prefill" else None

    elif fam == "hybrid":
        wins = layer_windows(cfg, cfg.n_layers, long_context)

        def body(xx, blk, win, st=None):
            xx, nkv, nssm, nconv = _hymba_block(
                cfg, blk, xx, positions, window=win,
                cache=st["kv"] if st is not None else None,
                ssm_state=st["ssm"] if st is not None else None,
                conv_state=st["conv"] if st is not None else None)
            out_st = {"kv": nkv, "ssm": nssm, "conv": nconv}
            return xx, out_st

        if cache is not None:
            x, new_cache = _scan_blocks(lambda c, blk, w, st: body(c, blk, w, st),
                                        x, p["blocks"], wins, cache)
        else:
            x, states = _scan_blocks(lambda c, blk, w: body(c, blk, w),
                                     x, p["blocks"], wins)
            # train mode: attention ran cache-less -> states' kv is None
            new_cache = None
            if mode == "prefill":
                raise ValueError("hybrid prefill requires a cache "
                                 "(init_cache) so the kv ring fills")

    elif fam == "vlm":
        if aux_inputs is not None:
            ctx = aux_inputs["patches"].astype(x.dtype) @ p["vision_proj"]
        else:
            ctx = cache["ctx"]
        per = cfg.cross_attn_period
        n_super = cfg.n_layers // per
        n_self = per - 1
        vwin = cfg.long_context_window if long_context else L.BIG_WINDOW

        def body(carry, blks, xblk, kv=None):
            xx, aux = carry

            def inner(c2, blk, kv_i=None):
                x2, a2 = c2
                x2, nkv, a = _decoder_block(cfg, blk, x2, positions,
                                            window=vwin, cache=kv_i)
                return (x2, a2 + a), nkv

            if kv is not None:
                (xx, aux), nkv = _scan_blocks(
                    lambda c, blk, kv_i: inner(c, blk, kv_i), (xx, aux), blks, kv)
            else:
                (xx, aux), nkv = _scan_blocks(
                    lambda c, blk: inner(c, blk), (xx, aux), blks)
            xx = _cross_block(cfg, xblk, xx, positions, ctx)
            return (xx, aux), nkv

        if cache is not None:
            kv_nested = jax.tree.map(
                lambda a: a.reshape((n_super, n_self) + a.shape[1:]), cache["kv"])
            (x, aux_total), nkv = _scan_blocks(
                lambda c, blks, xblk, kv: body(c, blks, xblk, kv),
                (x, aux_total), p["blocks"], p["cross_blocks"], kv_nested)
            new_kv = jax.tree.map(
                lambda a: a.reshape((n_super * n_self,) + a.shape[2:]), nkv)
            new_cache = {"kv": new_kv, "ctx": ctx}
        else:
            (x, aux_total), _ = _scan_blocks(
                lambda c, blks, xblk: body(c, blks, xblk),
                (x, aux_total), p["blocks"], p["cross_blocks"])
            new_cache = None

    elif fam == "audio":
        if aux_inputs is not None:
            frames = aux_inputs["frames"].astype(x.dtype) @ p["audio_adapter"]
            Te = frames.shape[1]
            enc_pos = jnp.broadcast_to(jnp.arange(Te, dtype=jnp.int32), (B, Te))
            enc_out, _ = _scan_blocks(
                lambda c, blk: (_encoder_block(cfg, blk, c, enc_pos), None),
                frames, p["enc_blocks"])
            ctx = L.apply_norm(cfg, p["enc_norm"], enc_out)
        else:
            ctx = cache["ctx"]
        awin = cfg.long_context_window if long_context else L.BIG_WINDOW

        def body(carry, blk, xblk, kv=None):
            xx, aux = carry
            xx, nkv, a = _decoder_block(cfg, blk, xx, positions,
                                        window=awin, cache=kv)
            xx = _cross_block(cfg, xblk, xx, positions, ctx)
            return (xx, aux + a), nkv

        if cache is not None:
            (x, aux_total), nkv = _scan_blocks(
                lambda c, blk, xblk, kv: body(c, blk, xblk, kv),
                (x, aux_total), p["blocks"], p["cross_blocks"], cache["kv"])
            new_cache = {"kv": nkv, "ctx": ctx}
        else:
            (x, aux_total), _ = _scan_blocks(
                lambda c, blk, xblk: body(c, blk, xblk),
                (x, aux_total), p["blocks"], p["cross_blocks"])
            new_cache = None
    else:
        raise ValueError(fam)

    return x, new_cache, aux_total


def forward(cfg, p, tokens, *, mode: str = "train", cache=None,
            positions=None, aux_inputs=None, long_context: bool = False):
    """Unified forward. Returns (logits_f32, new_cache, aux_losses).

    tokens: (B, S) int32. decode: S == 1 and ``positions`` is (B,) absolute.
    """
    x, new_cache, aux = _forward_hidden(
        cfg, p, tokens, mode=mode, cache=cache, positions=positions,
        aux_inputs=aux_inputs, long_context=long_context)
    return _head(cfg, p, x), new_cache, aux


def forward_features(cfg, p, tokens, *, aux_inputs=None):
    """Mean-pooled, L2-normalized final hidden state — the representation
    fed to the dual-temperature SSL loss for token architectures."""
    x, _, aux = _forward_hidden(cfg, p, tokens, mode="train", cache=None,
                                positions=None, aux_inputs=aux_inputs,
                                long_context=False)
    x = L.apply_norm(cfg, p["final_norm"], x)
    f = x.mean(axis=1).astype(jnp.float32)
    f = f / jnp.maximum(jnp.linalg.norm(f, axis=-1, keepdims=True), 1e-8)
    return f, aux
