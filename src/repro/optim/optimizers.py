"""Pytree optimizers + LR schedules (no optax in this container).

Each optimizer is (init, update) over arbitrary parameter pytrees:
    state = init(params)
    params, state = update(params, grads, state, lr)

The paper trains with SGD(momentum=0.9, weight_decay=5e-4) under a
cosine-annealed lr starting at 0.9 (Table 1). AdamW is provided for the
token-architecture training paths.
"""
from __future__ import annotations

import math
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class SGDState(NamedTuple):
    momentum: object


def sgd(momentum: float = 0.9, weight_decay: float = 5e-4, nesterov: bool = False):
    def init(params):
        return SGDState(momentum=jax.tree.map(jnp.zeros_like, params))

    def update(params, grads, state, lr):
        def upd(p, g, m):
            g = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
            m_new = momentum * m.astype(jnp.float32) + g
            step = (g + momentum * m_new) if nesterov else m_new
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), \
                m_new.astype(m.dtype)

        out = jax.tree.map(upd, params, grads, state.momentum)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, SGDState(momentum=new_m)

    return init, update


class AdamWState(NamedTuple):
    mu: object
    nu: object
    count: jnp.ndarray


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1):
    def init(params):
        return AdamWState(mu=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                          nu=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
                          count=jnp.zeros((), jnp.int32))

    def update(params, grads, state, lr):
        c = state.count + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu_n = b1 * mu + (1 - b1) * g
            nu_n = b2 * nu + (1 - b2) * g * g
            step = (mu_n / bc1) / (jnp.sqrt(nu_n / bc2) + eps)
            p_new = p.astype(jnp.float32) - lr * (step + weight_decay * p.astype(jnp.float32))
            return p_new.astype(p.dtype), mu_n, nu_n

        out = jax.tree.map(upd, params, grads, state.mu, state.nu)
        leaf = lambda t: isinstance(t, tuple)
        return (jax.tree.map(lambda t: t[0], out, is_leaf=leaf),
                AdamWState(mu=jax.tree.map(lambda t: t[1], out, is_leaf=leaf),
                           nu=jax.tree.map(lambda t: t[2], out, is_leaf=leaf),
                           count=c))

    return init, update


# --------------------------------------------------------------------------
# schedules
# --------------------------------------------------------------------------

def cosine_schedule(base_lr: float, total_steps: int, min_lr: float = 0.0,
                    warmup: int = 0) -> Callable:
    """Cosine annealing (paper Sec. 5.1: lr 0.9 annealed over training)."""
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos) if warmup else cos
    return lr


def constant_schedule(base_lr: float) -> Callable:
    return lambda step: jnp.asarray(base_lr, jnp.float32)
