"""Async RSU serving tier — model distribution beside the round engine.

The learner/actor split for vehicular FL (ROADMAP item 3, after the
Ape-X architecture): `run_campaign` is the learner, publishing each new
global model into a `ModelStore` of immutable (round, codec, payload)
snapshots; `RSUServer` is the distribution actor, answering vehicle
fetches from those snapshots with request batching and admission
control, so millions of vehicles can pull models without ever blocking
a training round. See DESIGN.md §Serving tier.
"""
from repro.serve.server import (PendingFetch, Reply, RSUServer, ServePolicy,
                                apply_reply, build_reply)
from repro.serve.store import ModelStore, Snapshot

__all__ = [
    "ModelStore",
    "PendingFetch",
    "Reply",
    "RSUServer",
    "ServePolicy",
    "Snapshot",
    "apply_reply",
    "build_reply",
]
