"""Batched RSU model distribution with admission control — `RSUServer`.

The actor half of the learner/actor split (Ape-X style): vehicles call
``submit(have_round)`` and get a `PendingFetch`; a batcher thread
drains the bounded request queue in batches (``max_batch`` requests,
waiting at most ``max_wait_s`` to coalesce more), groups each batch by
the round the vehicle already holds, and builds ONE reply per group —
one store lookup/encode serves every coalesced request. Replies are:

  kind="current"  the vehicle already holds the latest published round;
  kind="delta"    the per-round delta payload chain from the held round
                  to the latest snapshot (``<= max_lag`` hops);
  kind="full"     the staleness fallback — too far behind for a delta
                  chain (or the chain was evicted), ship the full tree
                  (the serving analogue of handover's stale-upload
                  discounting: stale state is not trusted to chain);
  status="shed"   admission control — the bounded queue was full, the
                  reply carries an explicit ``retry_after_s`` instead
                  of queueing unboundedly. A request is NEVER dropped
                  silently: every submit resolves exactly once, as a
                  payload or as a shed with backpressure.

Threading model: `submit` is safe from any number of vehicle threads;
the batcher is either the internal daemon thread (``start=True``) or
driven manually with ``drain_once(block=False)`` — the deterministic
mode the property tests interleave by hand. All reply construction is
host-side bookkeeping over pre-encoded payloads; nothing here ever
blocks the learner.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional

from repro.comms.codecs import decode_snapshot
from repro.serve.store import ModelStore

__all__ = ["PendingFetch", "Reply", "RSUServer", "ServePolicy",
           "apply_reply", "build_reply"]


@dataclass(frozen=True)
class ServePolicy:
    """Batching + admission-control knobs for one `RSUServer`.

    max_batch      requests answered per drain (coalescing bound)
    max_wait_s     how long a non-full batch waits for more requests
    queue_limit    admission bound: submits beyond this many queued
                   requests are shed with ``retry_after_s``
    max_lag        staleness cutoff in published-snapshot hops: a
                   vehicle further behind gets the full tree, not a
                   delta chain
    retry_after_s  backpressure hint carried by shed replies
    """

    max_batch: int = 256
    max_wait_s: float = 0.001
    queue_limit: int = 4096
    max_lag: int = 4
    retry_after_s: float = 0.05

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, "
                             f"got {self.queue_limit}")
        if self.max_lag < 0:
            raise ValueError(f"max_lag must be >= 0, got {self.max_lag}")


@dataclass(frozen=True)
class Reply:
    """One fetch outcome. ``payloads`` is ``((round, payload), ...)`` in
    application order; `apply_reply` folds it into the vehicle's tree."""

    status: str                  # "ok" | "shed"
    round: int = -1              # round the payloads bring the vehicle to
    kind: str = ""               # "current" | "delta" | "full"
    base_round: int = -1         # delta chains apply on top of this round
    payloads: tuple = ()
    retry_after_s: float = 0.0


class PendingFetch:
    """Future-like handle for one submitted fetch. Resolves exactly
    once (`_resolve` raises on a second resolution — the
    answered-twice guard the property suite leans on)."""

    __slots__ = ("have_round", "t_submit", "_event", "_reply")

    def __init__(self, have_round: int):
        self.have_round = int(have_round)
        self.t_submit = time.perf_counter()
        self._event = threading.Event()
        self._reply: Optional[Reply] = None

    def _resolve(self, reply: Reply) -> None:
        if self._event.is_set():
            raise RuntimeError("fetch answered twice")
        self._reply = reply
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> Reply:
        if not self._event.wait(timeout):
            raise TimeoutError(f"no reply within {timeout}s")
        return self._reply


def build_reply(store: ModelStore, policy: ServePolicy,
                have_round: int) -> Reply:
    """The one reply for every coalesced request holding ``have_round``:
    delta chain when linked and within ``max_lag`` hops, full tree when
    stale/unlinked, "current" when already at the latest round. An
    empty store answers shed-with-retry (the RSU has nothing to serve
    yet — explicit backpressure, not an error)."""
    chain = store.chain_from(have_round)
    if chain and len(chain) <= policy.max_lag:
        return Reply(status="ok", round=chain[-1].round, kind="delta",
                     base_round=have_round,
                     payloads=tuple((s.round, s.delta_payload)
                                    for s in chain))
    latest = store.latest()
    if latest is None:
        return Reply(status="shed", retry_after_s=policy.retry_after_s)
    if have_round >= latest.round:
        return Reply(status="ok", round=latest.round, kind="current",
                     base_round=latest.round)
    return Reply(status="ok", round=latest.round, kind="full",
                 payloads=((latest.round,
                            store.full_payload(latest.round)),))


def apply_reply(reply: Reply, have_tree, codec="delta"):
    """Vehicle-side decode: fold a Reply into the locally-held model.
    Full payloads replace the tree; delta payloads chain on top of it
    (each hop's output is the next hop's base); "current" keeps it."""
    if reply.status != "ok":
        raise ValueError(f"cannot apply a {reply.status!r} reply; retry "
                         f"after {reply.retry_after_s}s")
    if reply.kind == "current":
        return have_tree
    if reply.kind == "full":
        ((_rnd, payload),) = reply.payloads
        return decode_snapshot("identity", payload, None)
    tree = have_tree
    for _rnd, payload in reply.payloads:
        tree = decode_snapshot(codec, payload, tree)
    return tree


class RSUServer:
    """Bounded-queue, batching model-distribution server over one
    `ModelStore`. ``start=True`` runs the batcher as a daemon thread;
    ``start=False`` leaves draining to the caller (tests, and the
    benchmark's shed-path exercise where the queue must fill)."""

    def __init__(self, store: ModelStore, policy: Optional[ServePolicy] = None,
                 start: bool = True):
        self.store = store
        self.policy = policy or ServePolicy()
        self._cv = threading.Condition()
        self._queue: "deque[PendingFetch]" = deque()
        self._stats = {"submitted": 0, "served": 0, "shed": 0,
                       "batches": 0, "groups": 0, "max_depth": 0}
        self._stopped = False
        self._thread: Optional[threading.Thread] = None
        if start:
            self.start()

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        if self._thread is not None:
            raise RuntimeError("server already started")
        self._thread = threading.Thread(target=self._serve_loop,
                                        name="rsu-serve", daemon=True)
        self._thread.start()

    def stop(self, drain: bool = True) -> None:
        """Stop accepting requests and answer everything still queued —
        served (``drain=True``) or shed with retry-after (``False``).
        Either way no admitted request is ever lost."""
        with self._cv:
            self._stopped = True
            self._cv.notify_all()
        if self._thread is not None:
            self._thread.join(timeout=30.0)
            self._thread = None
        if drain:
            while self.drain_once(block=False):
                pass
        else:
            with self._cv:
                leftovers = list(self._queue)
                self._queue.clear()
                self._stats["shed"] += len(leftovers)
            shed = Reply(status="shed",
                         retry_after_s=self.policy.retry_after_s)
            for req in leftovers:
                req._resolve(shed)

    # -- vehicle side --------------------------------------------------------

    def submit(self, have_round: int) -> PendingFetch:
        """Enqueue one fetch. Admission control happens HERE: if the
        bounded queue is full (or the server is stopped), the returned
        handle is already resolved as a shed reply with an explicit
        retry-after — submit never blocks and never queues unboundedly."""
        req = PendingFetch(have_round)
        shed = None
        with self._cv:
            self._stats["submitted"] += 1
            if self._stopped or len(self._queue) >= self.policy.queue_limit:
                self._stats["shed"] += 1
                shed = Reply(status="shed",
                             retry_after_s=self.policy.retry_after_s)
            else:
                self._queue.append(req)
                depth = len(self._queue)
                if depth > self._stats["max_depth"]:
                    self._stats["max_depth"] = depth
                self._cv.notify()
        if shed is not None:
            req._resolve(shed)
        return req

    @property
    def pending(self) -> int:
        with self._cv:
            return len(self._queue)

    def stats(self) -> dict:
        with self._cv:
            return dict(self._stats)

    # -- batcher -------------------------------------------------------------

    def _collect(self, block: bool, timeout: Optional[float]) -> list:
        """Pop up to ``max_batch`` requests; in blocking mode a non-full
        batch waits ``max_wait_s`` for more (the coalescing window)."""
        wait_more = self.policy.max_wait_s if block else 0.0
        batch: list = []
        with self._cv:
            if block and not self._queue and not self._stopped:
                self._cv.wait_for(
                    lambda: bool(self._queue) or self._stopped, timeout)
            deadline = time.monotonic() + wait_more
            while True:
                while self._queue and len(batch) < self.policy.max_batch:
                    batch.append(self._queue.popleft())
                if (not batch or self._stopped
                        or len(batch) >= self.policy.max_batch):
                    break
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                self._cv.wait(left)
        return batch

    def drain_once(self, block: bool = True,
                   timeout: Optional[float] = None) -> int:
        """Serve one batch; returns how many requests were answered.
        The daemon thread loops this; tests call it directly for
        deterministic interleavings."""
        batch = self._collect(block, timeout)
        if not batch:
            return 0
        replies: dict = {}
        for req in batch:
            reply = replies.get(req.have_round)
            if reply is None:
                reply = build_reply(self.store, self.policy, req.have_round)
                replies[req.have_round] = reply
            req._resolve(reply)
        with self._cv:
            self._stats["served"] += len(batch)
            self._stats["batches"] += 1
            self._stats["groups"] += len(replies)
        return len(batch)

    def _serve_loop(self) -> None:
        while True:
            with self._cv:
                if self._stopped and not self._queue:
                    return
            self.drain_once(block=True, timeout=0.05)
