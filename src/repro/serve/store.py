"""Immutable model snapshots for the RSU serving tier — `ModelStore`.

The store is the boundary between the learner (the round engine) and
the distribution actors (serve/server.py): `run_campaign(publish=...)`
hands it ``(round, global_tree)`` at the once-per-chunk history fetch,
and the store turns each publication into an immutable `Snapshot`
holding

  tree            the exact ``FLState.global_tree`` as published;
  served_tree     what a vehicle holds after decoding the snapshot —
                  bitwise ``tree`` for lossless codecs; for lossy ones
                  the server-side reconstruction (see below);
  delta_payload   ``encode_snapshot(codec, tree, prev.served_tree)``,
                  encoded ONCE at publish time through the `CODECS`
                  registry — a vehicle already holding the previous
                  published round fetches this payload, not the full
                  tree;
  full payload    identity framing of ``served_tree``, built lazily on
                  the first stale fetch and cached (one encode, N
                  replies — the staleness fallback).

**Lossy codecs chain off the reconstruction.** A delta_int8 snapshot
encodes θ_r against the previous *served* tree θ̂_{r-1} (not the exact
θ_{r-1}) and publishes θ̂_r = decode(payload, θ̂_{r-1}) as the next
base. Every vehicle that applies the same payloads runs the same
deterministic decode on the same inputs, so vehicle state is BITWISE
equal to ``served_tree`` whether it arrived by delta chain or by full
fallback — quantization error never forks the fleet (property-pinned
in tests/test_serve_properties.py).

Publishes are assumed to come from ONE learner (rounds strictly
increasing — the `run_campaign`/`run` hooks guarantee it); fetch-side
reads (`chain_from`, `full_payload`, `latest`) are thread-safe against
a concurrent publish. Retention is bounded by ``window`` snapshots;
evicting an intermediate snapshot breaks the delta-chain linkage and
`chain_from` answers None, which the server turns into the full-tree
fallback — never a wrong payload.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from repro.comms.codecs import (CODECS, decode_snapshot, encode_snapshot,
                                payload_nbytes)

__all__ = ["ModelStore", "Snapshot"]


@dataclass
class Snapshot:
    """One published (round, codec, payload) model snapshot.

    Immutable once published, except the lazily-built full-payload
    cache (`ModelStore.full_payload` guards it with the store lock).
    """

    round: int
    base_round: Optional[int]        # published round the delta chains from
    tree: Any                        # the exact published global model
    served_tree: Any                 # the vehicle-side reconstruction
    delta_payload: Optional[dict]    # encoded once; None for the first snap
    _full: Optional[dict] = field(default=None, repr=False)

    @property
    def delta_nbytes(self) -> Optional[int]:
        return (None if self.delta_payload is None
                else payload_nbytes(self.delta_payload))


class ModelStore:
    """Round-indexed snapshot store published by the round engine.

    codec    `CODECS` name framing the delta payloads (default the
             lossless ``delta`` — served trees decode bitwise equal to
             the published model)
    window   how many snapshots stay fetchable; older ones are evicted
             and very stale vehicles fall back to the full tree
    """

    def __init__(self, codec: str = "delta", window: int = 8):
        if codec not in CODECS:
            raise ValueError(f"unknown codec {codec!r}; valid: "
                             f"{sorted(CODECS)}")
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        self.codec = codec
        self.window = window
        self._lock = threading.Lock()
        self._snaps: "OrderedDict[int, Snapshot]" = OrderedDict()
        self._stats = {"publishes": 0, "delta_encodes": 0, "full_encodes": 0}

    # -- publish (the learner side) -----------------------------------------

    def publish(self, rnd: int, tree) -> Snapshot:
        """Ingest the new global model for round ``rnd`` — the target of
        the `run_campaign(publish=store.publish)` hook. Encodes the
        delta payload ONCE (outside the lock: fetches keep flowing
        against the existing snapshots meanwhile) and never touches the
        host — ``tree`` stays whatever device arrays the engine holds,
        so publishing adds no device syncs to the compiled path."""
        rnd = int(rnd)
        with self._lock:
            prev = (next(reversed(self._snaps.values()))
                    if self._snaps else None)
        if prev is not None and rnd <= prev.round:
            raise ValueError(f"publish rounds must increase: got {rnd} "
                             f"after {prev.round}")
        codec = CODECS[self.codec]
        if prev is None:
            payload, served, base_round = None, tree, None
        else:
            payload = encode_snapshot(codec, tree, prev.served_tree)
            served = (tree if codec.lossless
                      else decode_snapshot(codec, payload, prev.served_tree))
            base_round = prev.round
        snap = Snapshot(round=rnd, base_round=base_round, tree=tree,
                        served_tree=served, delta_payload=payload)
        with self._lock:
            self._snaps[rnd] = snap
            self._stats["publishes"] += 1
            if payload is not None:
                self._stats["delta_encodes"] += 1
            while len(self._snaps) > self.window:
                self._snaps.popitem(last=False)
        return snap

    # -- fetch-side reads ----------------------------------------------------

    @property
    def latest_round(self) -> Optional[int]:
        with self._lock:
            return next(reversed(self._snaps)) if self._snaps else None

    def latest(self) -> Optional[Snapshot]:
        with self._lock:
            return (next(reversed(self._snaps.values()))
                    if self._snaps else None)

    def get(self, rnd: int) -> Optional[Snapshot]:
        with self._lock:
            return self._snaps.get(rnd)

    def rounds(self) -> List[int]:
        with self._lock:
            return list(self._snaps)

    def chain_from(self, have_round: int) -> Optional[List[Snapshot]]:
        """The delta chain a vehicle holding published round
        ``have_round`` applies to reach the latest snapshot: every
        retained snapshot strictly newer than ``have_round``, in
        application order. Empty list = already up to date. None = no
        valid chain (the linkage is broken by eviction, or the vehicle's
        round was never a chain base) — serve the full tree instead."""
        with self._lock:
            newer = [s for r, s in self._snaps.items() if r > have_round]
        prev = have_round
        for s in newer:
            if s.base_round != prev or s.delta_payload is None:
                return None
            prev = s.round
        return newer

    def full_payload(self, rnd: int) -> dict:
        """Identity-framed full tree for round ``rnd`` — the staleness
        fallback payload. Encoded ONCE on the first request and cached;
        the server's batcher coalesces N concurrent stale fetches into
        this single lookup."""
        with self._lock:
            snap = self._snaps.get(rnd)
            if snap is None:
                raise KeyError(f"round {rnd} is not retained "
                               f"(have: {list(self._snaps)})")
            if snap._full is None:
                snap._full = encode_snapshot("identity", snap.served_tree,
                                             None)
                self._stats["full_encodes"] += 1
            return snap._full

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._stats)
