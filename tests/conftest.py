"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers",
        "multidevice: needs multiple jax devices (tests/multidevice/ runs "
        "in a subprocess with XLA_FLAGS=--xla_force_host_platform_"
        "device_count=8; this conftest imports jax, so forcing cannot "
        "happen in-process)")
