"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
