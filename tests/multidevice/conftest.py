"""Multi-device (forced 8-CPU-device) suite.

Everything under tests/multidevice/ assumes `jax.device_count() >= 8`.
The top-level tests/conftest.py deliberately sets no XLA_FLAGS (tier-1
must see the single real device) and imports jax, so device forcing
cannot happen in this process once tier-1 has started — instead
tests/test_sharded_cohort.py drives this directory in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8, and the CI
`multidevice` job exports the flag before invoking pytest directly.
Run by hand with:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m pytest tests/multidevice -q

When fewer than 8 devices are visible every test here skips cleanly.
"""
import jax
import pytest


def pytest_collection_modifyitems(config, items):
    marker = pytest.mark.multidevice
    skip = pytest.mark.skip(
        reason=f"needs 8 jax devices, have {jax.device_count()} — set "
               "XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
               "jax imports (or run tests/test_sharded_cohort.py, which "
               "spawns the forced subprocess)")
    for item in items:
        if "tests/multidevice" in str(item.fspath).replace("\\", "/"):
            item.add_marker(marker)
            if jax.device_count() < 8:
                item.add_marker(skip)
