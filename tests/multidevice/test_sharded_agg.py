"""Sharded-cohort aggregation: the bit-exactness contracts under a real
(pod, data) mesh (DESIGN.md §Sharded cohorts), on forced 8-CPU devices.

Layers pinned here:

  1. `sharded_aggregate` ("gather" and "split") is BITWISE identical to
     the single-device `AGGREGATORS` dispatch for all five schemes, on
     both weighted-sum backends, including padding edge cases (cohort
     smaller than the mesh, all-invalid shards).
  2. `sharded_hierarchical` reduction="exact" is bitwise with
     `aggregate_hierarchical`; reduction="psum" (the blocked
     `two_stage_weighted_psum` collective) is float-close (atol 1e-5).
  3. `MultiRSU` auto-promotes to the mesh (mesh_aggregate=None default)
     and the sequential-client mesh round stays bitwise with the host
     round; the parallel sharded round is deterministic and float-close
     versus host (the block-sharded vmap batches at a different width —
     never bitwise, by design).
  4. `run_cohort(mesh=...)` shards client execution with valid-prefix
     semantics intact; `CohortBatch.shard`/`gather` round-trip values.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.aggregation import AGGREGATORS, SCHEME_WEIGHTS
from repro.core.cohort import CohortBatch
from repro.core.hierarchical import (aggregate_hierarchical,
                                     sharded_aggregate,
                                     sharded_cohort_sum,
                                     sharded_hierarchical)
from repro.core.state import FLConfig
from repro.launch.mesh import cohort_mesh, maybe_cohort_mesh

pytestmark = []  # marker applied by conftest


def _stacked_trees(key, m, shapes=((4, 3), (7,))):
    return {"a": jax.random.normal(key, (m,) + shapes[0]),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1),
                                         (m,) + shapes[1])}}


def _cohort(key, n, m, blur=None):
    trees = _stacked_trees(key, m)
    losses = jax.random.uniform(jax.random.fold_in(key, 2), (m,))
    if blur is None:
        blur = jax.random.uniform(jax.random.fold_in(key, 3), (n,),
                                  minval=10.0, maxval=20.0)
    blur_pad = jnp.concatenate(
        [jnp.asarray(blur, jnp.float32),
         jnp.full((m - n,), 99.0, jnp.float32)])  # garbage padding blur
    return CohortBatch.from_stacked(trees, losses, n=n, blur=blur_pad)


def _assert_trees_equal(t1, t2):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


MESH = lambda: cohort_mesh(2, 4)  # noqa: E731 — lazy, after device check


# --------------------------------------------------------------------------
# flat sharded aggregation: bitwise vs the single-device dispatch
# --------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["tree", "interpret"])
@pytest.mark.parametrize("name", sorted(AGGREGATORS))
def test_sharded_gather_bit_exact_all_schemes(name, backend):
    """Acceptance: sharded aggregation == single-device cohort path,
    bit for bit, all five schemes, both backends, with padding."""
    cfg = FLConfig(aggregator=name)
    # straddle the default blur_threshold so "discard" keeps a subset
    c = _cohort(jax.random.PRNGKey(0), n=5, m=8,
                blur=jnp.array([11.6, 17.4, 12.8, 19.0, 14.2]))
    with agg.wagg_backend(backend):
        ref = AGGREGATORS[name](c, cfg)
        got = sharded_aggregate(c, cfg, MESH())
    _assert_trees_equal(ref, got)


@pytest.mark.parametrize("name", sorted(AGGREGATORS))
def test_sharded_split_bit_exact_vs_tree_backend(name):
    """The all-to-all parameter-sharded reduction preserves the row
    summation order of the single-device tensordot — bitwise with the
    tree backend, at O(m*P/devices) per-device memory."""
    cfg = FLConfig(aggregator=name)
    c = _cohort(jax.random.PRNGKey(1), n=6, m=8)
    with agg.wagg_backend("tree"):
        ref = AGGREGATORS[name](c, cfg)
    got = sharded_aggregate(c, cfg, MESH(), reduction="split")
    _assert_trees_equal(ref, got)


def test_cohort_smaller_than_mesh():
    """m=3 over an 8-way mesh: pad_to(8) fills whole shards with
    replicated finite rows whose zero weights make them exact no-ops."""
    cfg = FLConfig(aggregator="flsimco")
    c = _cohort(jax.random.PRNGKey(2), n=2, m=3)
    ref = AGGREGATORS["flsimco"](c, cfg)
    for reduction in ("gather", "split"):
        got = sharded_aggregate(c, cfg, MESH(), reduction=reduction)
        _assert_trees_equal(ref, got)


def test_all_invalid_shard():
    """n=2 of m=8: devices past the valid prefix hold ONLY padding —
    their shard contributes exact +0.0 and the result stays bitwise."""
    cfg = FLConfig(aggregator="fedavg")
    c = _cohort(jax.random.PRNGKey(3), n=2, m=8)
    ref = AGGREGATORS["fedavg"](c, cfg)
    got = sharded_aggregate(c, cfg, MESH())
    _assert_trees_equal(ref, got)


def test_sharded_cohort_sum_explicit_weights_and_errors():
    c = _cohort(jax.random.PRNGKey(4), n=4, m=8)
    w = jnp.array([0.4, 0.3, 0.2, 0.1])
    ref = agg.cohort_weighted_sum(c, w)
    _assert_trees_equal(ref, sharded_cohort_sum(c, w, MESH()))
    with pytest.raises(ValueError, match="reduction"):
        sharded_cohort_sum(c, w, MESH(), reduction="magic")


def test_sharded_input_may_already_live_on_the_mesh():
    """shard() then aggregate: device placement must not change values."""
    cfg = FLConfig(aggregator="softmax")
    c = _cohort(jax.random.PRNGKey(5), n=8, m=8)
    ref = AGGREGATORS["softmax"](c, cfg)
    sharded = c.shard(MESH())
    got = sharded_aggregate(sharded, cfg, MESH())
    _assert_trees_equal(ref, got)
    back = sharded.gather()
    _assert_trees_equal(c.trees, back.trees)
    assert back.n == c.n


# --------------------------------------------------------------------------
# hierarchical (two-level Eq. 11) under the mesh
# --------------------------------------------------------------------------

def _hier_case(key, R=2, s=4):
    trees = _stacked_trees(key, R * s)
    blur = jax.random.uniform(jax.random.fold_in(key, 7), (R * s,),
                              minval=10.0, maxval=20.0)
    cohorts = []
    for r in range(R):
        sl = slice(r * s, (r + 1) * s)
        cohorts.append(CohortBatch.from_stacked(
            jax.tree.map(lambda x: x[sl], trees),
            jnp.zeros((s,))).with_stats(blur=blur[sl]))
    return trees, blur, cohorts


@pytest.mark.parametrize("count_scaled", [True, False])
def test_sharded_hierarchical_exact_bitwise(count_scaled):
    trees, blur, cohorts = _hier_case(jax.random.PRNGKey(10))
    ref = aggregate_hierarchical(cohorts, count_scaled=count_scaled)
    got = sharded_hierarchical(trees, blur, MESH(), 2,
                               count_scaled=count_scaled)
    _assert_trees_equal(ref, got)


def test_sharded_hierarchical_psum_float_close():
    """The blocked two_stage_weighted_psum collective: one model per
    device on the wire, reassociated row sums — float-close only."""
    trees, blur, cohorts = _hier_case(jax.random.PRNGKey(11))
    ref = aggregate_hierarchical(cohorts)
    got = sharded_hierarchical(trees, blur, MESH(), 2, reduction="psum")
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_sharded_hierarchical_rejects_bad_shapes():
    trees, blur, _ = _hier_case(jax.random.PRNGKey(12))
    with pytest.raises(ValueError, match="divisible"):
        sharded_hierarchical(trees, blur[:7], MESH(), 2)
    with pytest.raises(ValueError, match="reduction"):
        sharded_hierarchical(trees, blur, MESH(), 2, reduction="magic")


# --------------------------------------------------------------------------
# topology + client integration
# --------------------------------------------------------------------------

def _tiny_scenario(**over):
    from repro.core.scenario import Scenario
    rng = np.random.RandomState(0)
    data = [rng.rand(6, 4, 4, 3).astype(np.float32) for _ in range(8)]
    kw = dict(data=data, n_vehicles=8, vehicles_per_round=4, batch_size=2,
              rounds=2, local_iters=1, lr=0.4, seed=11,
              topology="multi", topology_kwargs={"n_rsus": 2})
    kw.update(over)
    return Scenario(**kw)


def test_multi_rsu_auto_promotes_to_mesh():
    """mesh_aggregate=None (the default) resolves a real multi-device
    mesh whenever the cohort splits evenly — sharded by default."""
    sc = _tiny_scenario()
    mesh = sc.topology.resolve_mesh(sc.cfg)
    assert mesh is not None and mesh.size > 1
    assert dict(mesh.shape) == {"pod": 2, "data": 2}
    # uneven cohorts fall back to host silently under auto...
    sc_odd = _tiny_scenario(vehicles_per_round=3)
    assert sc_odd.topology.resolve_mesh(sc_odd.cfg) is None
    # ...and raise actionably when the mesh is forced
    from repro.core.topology import MultiRSU
    with pytest.raises(ValueError, match="mesh_aggregate"):
        MultiRSU(n_rsus=2, mesh_aggregate=True).resolve_mesh(
            sc_odd.cfg)


def test_sequential_mesh_round_bitwise_vs_host():
    """parallel=False + mesh: client execution is the sequential host
    reference, only the aggregation shards — the whole round is bitwise
    with the host path ("exact" reduction is a reordering-free gather)."""
    from repro.core.scenario import run
    from repro.core.topology import MultiRSU
    sc_mesh = _tiny_scenario()
    sc_host = _tiny_scenario(
        topology=MultiRSU(n_rsus=2, mesh_aggregate=False),
        topology_kwargs=None)
    st_m, h_m = run(sc_mesh, rounds=1, parallel=False)
    st_h, h_h = run(sc_host, rounds=1, parallel=False)
    _assert_trees_equal(st_m.global_tree, st_h.global_tree)
    assert h_m[0]["loss"] == h_h[0]["loss"]


def test_parallel_sharded_round_deterministic_and_close():
    """The fully sharded round (client blocks + reduction under
    shard_map): bitwise-deterministic within the mode, float-close
    versus the host path (different vmap width — documented, PR-6
    style)."""
    from repro.core.scenario import run
    from repro.core.topology import MultiRSU
    sc = _tiny_scenario()
    st1, h1 = run(sc, rounds=2)
    st2, h2 = run(sc, rounds=2)
    _assert_trees_equal(st1.global_tree, st2.global_tree)
    assert [r["loss"] for r in h1] == [r["loss"] for r in h2]
    sc_host = _tiny_scenario(
        topology=MultiRSU(n_rsus=2, mesh_aggregate=False),
        topology_kwargs=None)
    st_h, h_h = run(sc_host, rounds=1)
    st_m, h_m = run(sc, rounds=1)
    for a, b in zip(jax.tree.leaves(st_m.global_tree),
                    jax.tree.leaves(st_h.global_tree)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=1e-4)
    # schedule (everything but the loss) is bitwise-shared
    assert {k: v for k, v in h_m[0].items() if k != "loss"} == \
        {k: v for k, v in h_h[0].items() if k != "loss"}


def test_run_cohort_mesh_shapes_and_prefix():
    """run_cohort(mesh=...) pads to the mesh extent but keeps the
    valid-prefix contract: n stays the true cohort size."""
    from repro.core.clients import CLIENT_UPDATES
    sc = _tiny_scenario()
    state = sc.init_state()
    rng = np.random.RandomState(1)
    batches = jnp.asarray(rng.rand(3, 2, 4, 4, 3).astype(np.float32))
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    cohort, _ = CLIENT_UPDATES["dtssl"].run_cohort(
        sc.cfg, state.global_tree, None, batches, keys, 0.1,
        mesh=MESH())
    assert cohort.n == 3
    assert cohort.size == 8          # padded to the mesh extent
    assert bool(jnp.all(jnp.isfinite(cohort.valid_losses)))


def test_handover_mesh_shard_runs_with_device_side_regrouping():
    """HandoverMultiRSU(mesh_shard=True): download groups run sharded,
    uploads stay `CohortBatch.take` gathers — rounds complete with
    finite losses and per-RSU regrouping intact."""
    from repro.core.scenario import run
    sc = _tiny_scenario(
        topology="handover",
        topology_kwargs={"n_rsus": 2, "rsu_range": 200.0,
                         "round_duration": 50.0, "sync_every": 2,
                         "mesh_shard": True})
    st, hist = run(sc, rounds=2)
    assert all(np.isfinite(r["loss"]) for r in hist)
    assert sum(hist[0]["rsu_sizes"]) == sc.cfg.vehicles_per_round


def test_maybe_cohort_mesh_resolution():
    assert maybe_cohort_mesh(2, 4) is not None
    # largest divisor of rows_per_pod=4 with pod*data <= 8 devices
    assert dict(maybe_cohort_mesh(2, 4).shape) == {"pod": 2, "data": 4}
    # caching: the same shape is the same mesh object
    assert cohort_mesh(2, 4) is cohort_mesh(2, 4)
    # more pods than devices -> no mesh under auto
    assert maybe_cohort_mesh(16, 4) is None
