"""Sharded-path comms codec contracts under a real (pod, data) mesh.

The host-path bit-exactness of the lossless delta tier is pinned in
tests/test_comms.py; this module pins the SHARDED half of the
acceptance criterion: aggregating a delta-roundtripped cohort over the
mesh is bitwise identical to the single-device aggregation of the
original cohort, for all five schemes — and the sharded MultiRSU round
with codec="delta" replays codec="identity" bit for bit.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.comms.codecs import CODECS, roundtrip_cohort
from repro.core.aggregation import AGGREGATORS
from repro.core.cohort import CohortBatch
from repro.core.hierarchical import sharded_aggregate, sharded_hierarchical
from repro.core.state import FLConfig
from repro.launch.mesh import cohort_mesh

pytestmark = []  # marker applied by conftest

MESH = lambda: cohort_mesh(2, 4)  # noqa: E731 — lazy, after device check


def _cohort(key, n, m):
    """n valid rows padded to m by pad_to (replicated last row — the
    padding roundtrip_cohort reproduces, so full-tree comparisons stay
    bitwise; arbitrary pad rows would be rewritten by the codec stage)."""
    trees = {"a": jax.random.normal(key, (n, 4, 3)),
             "b": {"c": jax.random.normal(jax.random.fold_in(key, 1),
                                          (n, 7))}}
    losses = jax.random.uniform(jax.random.fold_in(key, 2), (n,))
    blur = jax.random.uniform(jax.random.fold_in(key, 3), (n,),
                              minval=10.0, maxval=20.0)
    c = CohortBatch.from_stacked(trees, losses, n=n, blur=blur)
    return c.pad_to(m) if m > n else c


def _assert_trees_equal(t1, t2):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("name", sorted(AGGREGATORS))
def test_sharded_aggregate_of_delta_roundtrip_bitwise(name):
    """Acceptance: decode-then-aggregate over the mesh == the
    single-device aggregation of the ORIGINAL cohort, bit for bit, all
    five schemes — the sharded half of the lossless contract."""
    cfg = FLConfig(aggregator=name, codec="delta")
    c = _cohort(jax.random.PRNGKey(0), n=5, m=8)
    base = jax.tree.map(lambda x: x[0] * 0.5, c.trees)
    c_rt, _ = roundtrip_cohort(cfg, c, base, None)
    _assert_trees_equal(c_rt.trees, c.trees)       # reconstruction exact
    ref = AGGREGATORS[name](c, cfg)
    got = sharded_aggregate(c_rt, cfg, MESH())
    _assert_trees_equal(ref, got)


def test_sharded_hierarchical_of_delta_roundtrip_bitwise():
    cfg = FLConfig(aggregator="flsimco", codec="delta")
    c = _cohort(jax.random.PRNGKey(1), n=8, m=8)
    base = jax.tree.map(lambda x: x[0] + 1.0, c.trees)
    c_rt, _ = roundtrip_cohort(cfg, c, base, None)
    ref = sharded_hierarchical(c.trees, c.blur, MESH(), 2)
    got = sharded_hierarchical(c_rt.trees, c_rt.blur, MESH(), 2)
    _assert_trees_equal(ref, got)


def _tiny_scenario(**over):
    from repro.core.scenario import Scenario
    rng = np.random.RandomState(0)
    data = [rng.rand(6, 4, 4, 3).astype(np.float32) for _ in range(8)]
    kw = dict(data=data, n_vehicles=8, vehicles_per_round=4, batch_size=2,
              rounds=2, local_iters=1, lr=0.4, seed=11,
              topology="multi", topology_kwargs={"n_rsus": 2})
    kw.update(over)
    return Scenario(**kw)


def test_sharded_multi_rsu_round_delta_bitwise():
    """The sharded MultiRSU default path (mesh client blocks + sharded
    hierarchical reduce) with the codec stage inserted before the
    reduction: codec="delta" == codec="identity" bit for bit."""
    from repro.core.scenario import run
    sc_i = _tiny_scenario()
    sc_d = _tiny_scenario(codec="delta")
    assert sc_i.topology.resolve_mesh(sc_i.cfg) is not None
    st_i, h_i = run(sc_i, rounds=2)
    st_d, h_d = run(sc_d, rounds=2)
    _assert_trees_equal(st_i.global_tree, st_d.global_tree)
    assert h_i == h_d


def test_sharded_multi_rsu_round_int8_threads_ef():
    """The lossy tier on the sharded path: deterministic, EF residual
    live, permutation-consistent slots (rows=perm scatter)."""
    from repro.core.scenario import run
    sc = _tiny_scenario(codec="delta_int8", lr=0.05)
    st1, h1 = run(sc, rounds=2)
    st2, h2 = run(sc, rounds=2)
    _assert_trees_equal(st1.to_tree(), st2.to_tree())
    assert h1 == h2
    assert float(jnp.abs(st1.comms["ef"]).max()) > 0.0


def test_two_stage_psum_f64_accum_multidevice():
    """The f64 accumulator under a REAL 8-way psum: the cross-device
    reduction accumulates in f64 and lands within one f32 rounding of
    the exact host-f64 weighted sum; the default f32 psum does not, on
    this cancellation-heavy cohort. Blur levels are chosen so every
    weight-path reduction (sum L, sum w1) is EXACT in f32 regardless of
    psum association — dyadic partials — which pins the host reference
    weights bitwise to the device weights and isolates the value
    accumulation as the only error source."""
    mesh = cohort_mesh(1, 8)
    rng = np.random.RandomState(0)
    b = 8
    big = np.tile([3e4, -3e4], b // 2)[:, None]
    x = (rng.randn(b, 24) + big).astype(np.float32)
    trees = {"w": jnp.asarray(x)}
    # sum(L) = 128; w1 = (128 - L)/128 are multiples of 1/16 summing to
    # 7.0 — every partial sum exact in any order. Equal weights within
    # each (+3e4, -3e4) pair keep the big components cancelling exactly
    # in f64, so `expect` is O(1) and the f32 cast is the whole error.
    L = np.array([8, 8, 16, 16, 16, 16, 24, 24], np.float32)
    blur = jnp.asarray(L)
    w1 = (L.sum() - L) / L.sum()
    w1 = (w1 / w1.sum()).astype(np.float32)
    expect = np.tensordot(w1.astype(np.float64),
                          x.astype(np.float64), axes=1).astype(np.float32)
    got32 = sharded_hierarchical(trees, blur, mesh, 1, reduction="psum")
    with jax.experimental.enable_x64():
        got64 = sharded_hierarchical(trees, blur, mesh, 1,
                                     reduction="psum",
                                     accum_dtype=jnp.float64)
    assert got64["w"].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got64["w"]), expect,
                               atol=2e-6, rtol=1e-6)
    err32 = np.abs(np.asarray(got32["w"], np.float64) - expect).max()
    err64 = np.abs(np.asarray(got64["w"], np.float64) - expect).max()
    assert err64 <= err32
