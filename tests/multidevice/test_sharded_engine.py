"""Sharded `run_campaign`: the compiled path and the sharded path
compose (forced 8-CPU devices).

Mirrors tests/test_engine.py for the MultiRSU-on-mesh round body:

  * trace counts stay pinned — jit_round <= 1 program per campaign,
    scan <= 2 (the chunk body + remainder) — shard_map inlines into the
    jitted round instead of adding programs;
  * checkpoint save/restore at a chunk boundary replays the campaign
    BIT for bit within the sharded mode;
  * the schedule (every record field but the loss) is bitwise-identical
    to the eager sharded loop.
"""
import functools
import os

import jax
import numpy as np
import pytest

from repro.core.engine import compile_counts, run_campaign
from repro.core.scenario import Scenario, run


def _scenario(**over):
    rng = np.random.RandomState(0)
    data = [rng.rand(6, 4, 4, 3).astype(np.float32) for _ in range(8)]
    kw = dict(data=data, n_vehicles=8, vehicles_per_round=4, batch_size=2,
              rounds=4, local_iters=1, lr=0.4, seed=11,
              topology="multi", topology_kwargs={"n_rsus": 2})
    kw.update(over)
    return Scenario(**kw)


@functools.lru_cache(maxsize=None)
def _jit4():
    sc = _scenario()
    assert sc.topology.resolve_mesh(sc.cfg) is not None  # really sharded
    return sc, run_campaign(sc, rounds=4, mode="jit")


def _assert_states_identical(s1, s2):
    l1, l2 = jax.tree.leaves(s1.to_tree()), jax.tree.leaves(s2.to_tree())
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s1.round == s2.round


def test_sharded_campaign_trace_counts():
    sc, (st, hist) = _jit4()
    assert len(hist) == 4
    assert all(np.isfinite(r["loss"]) for r in hist)
    assert compile_counts(sc)["jit_round"] <= 1


def test_sharded_campaign_schedule_matches_eager_sharded():
    sc, (st, hist) = _jit4()
    st_e, hist_e = run(_scenario(), rounds=4)
    sans = lambda r: {k: v for k, v in r.items() if k != "loss"}
    assert [sans(r) for r in hist] == [sans(r) for r in hist_e]
    np.testing.assert_array_equal(np.asarray(st.key), np.asarray(st_e.key))


def test_sharded_checkpoint_resume_bit_exact(tmp_path):
    """Save at round 2, restore, run 2 more: bitwise with the
    uninterrupted sharded campaign (trees, losses, full FLState)."""
    from repro.checkpoint.store import restore_state
    sc, (st4, hist4) = _jit4()
    sc2 = _scenario()
    st_ck, hist_ck = run_campaign(sc2, rounds=4, mode="jit",
                                  checkpoint_every=2,
                                  checkpoint_dir=str(tmp_path))
    _assert_states_identical(st4, st_ck)
    assert hist_ck == hist4
    restored = restore_state(os.path.join(tmp_path, "round_000002"), sc2)
    assert restored.round == 2
    st_b, hist_b = run_campaign(sc2, restored, rounds=2, mode="jit")
    _assert_states_identical(st4, st_b)
    assert hist_ck[:2] + hist_b == hist4
    assert compile_counts(sc2)["jit_round"] <= 1


@pytest.mark.parametrize("mode", ["scan"])
def test_sharded_scan_chunks_compose(mode):
    sc = _scenario()
    st4, hist4 = run_campaign(sc, rounds=4, mode=mode)
    st_a, hist_a = run_campaign(sc, rounds=2, mode=mode)
    st_b, hist_b = run_campaign(sc, st_a, rounds=2, mode=mode)
    _assert_states_identical(st4, st_b)
    assert hist_a + hist_b == hist4
    assert compile_counts(sc)["scan"] <= 2
