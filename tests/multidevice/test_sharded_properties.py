"""Property tests: sharded == unsharded aggregation over random cohorts.

Hypothesis draws the cohort geometry (valid count, padded size, uneven
per-RSU splits) and the scheme; the invariant is always the same —
`sharded_aggregate` / `sharded_hierarchical("exact")` are BITWISE
identical to the single-device dispatch, whatever the padding or mesh
occupancy. hypothesis is a dev-only dependency (requirements-dev.txt):
the whole module skips when it is absent, same pattern as
tests/test_aggregation.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import aggregation as agg
from repro.core.aggregation import AGGREGATORS
from repro.core.cohort import CohortBatch
from repro.core.hierarchical import (aggregate_hierarchical,
                                     sharded_aggregate,
                                     sharded_hierarchical)
from repro.core.state import FLConfig
from repro.launch.mesh import cohort_mesh

SETTINGS = settings(max_examples=25, deadline=None)


def _cohort(seed, n, m):
    key = jax.random.PRNGKey(seed)
    trees = {"a": jax.random.normal(key, (m, 3, 2)),
             "b": jax.random.normal(jax.random.fold_in(key, 1), (m, 5))}
    blur = jax.random.uniform(jax.random.fold_in(key, 2), (n,),
                              minval=10.0, maxval=20.0)
    blur_pad = jnp.concatenate([blur, jnp.full((m - n,), 99.0)])
    return CohortBatch.from_stacked(
        trees, jnp.zeros((m,)), n=n, blur=blur_pad)


def _assert_trees_equal(t1, t2):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@SETTINGS
@given(seed=st.integers(0, 2**16),
       n=st.integers(1, 12),
       pad=st.integers(0, 5),
       scheme=st.sampled_from(sorted(AGGREGATORS)),
       reduction=st.sampled_from(["gather", "split"]))
def test_sharded_equals_unsharded_any_geometry(seed, n, pad, scheme,
                                               reduction):
    """Any valid count (including cohorts smaller than the 8-way mesh,
    whole all-invalid shards after re-padding), any scheme, both
    reductions: bitwise equality with the single-device path."""
    c = _cohort(seed, n, n + pad)
    cfg = FLConfig(aggregator=scheme)
    ref = AGGREGATORS[scheme](c, cfg)
    got = sharded_aggregate(c, cfg, cohort_mesh(2, 4), reduction=reduction)
    _assert_trees_equal(ref, got)


@SETTINGS
@given(seed=st.integers(0, 2**16),
       sizes=st.lists(st.integers(1, 6), min_size=2, max_size=4))
def test_hierarchical_uneven_rsu_cohorts_via_host_vs_padded_mesh(seed,
                                                                 sizes):
    """Uneven per-RSU cohort sizes: the mesh form requires equal blocks,
    so the equivalence is stated on the equalized cohort (every RSU
    padded to the max size never enters — instead we check the HOST
    hierarchical on uneven cohorts equals the mesh hierarchical on the
    same cohorts whenever they happen to be equal, and that the mesh
    path refuses uneven flat shapes instead of mis-aggregating)."""
    key = jax.random.PRNGKey(seed)
    R = len(sizes)
    cohorts, blocks = [], []
    for r, s in enumerate(sizes):
        k = jax.random.fold_in(key, r)
        trees = {"a": jax.random.normal(k, (s, 3, 2))}
        blur = jax.random.uniform(jax.random.fold_in(k, 1), (s,),
                                  minval=10.0, maxval=20.0)
        cohorts.append(CohortBatch.from_stacked(
            trees, jnp.zeros((s,))).with_stats(blur=blur))
        blocks.append((trees, blur))
    ref = aggregate_hierarchical(cohorts)
    assert all(np.isfinite(np.asarray(l)).all()
               for l in jax.tree.leaves(ref))
    if len(set(sizes)) == 1:
        stacked = jax.tree.map(lambda *ls: jnp.concatenate(ls),
                               *[t for t, _ in blocks])
        blur = jnp.concatenate([b for _, b in blocks])
        got = sharded_hierarchical(stacked, blur, cohort_mesh(R, 1), R)
        _assert_trees_equal(ref, got)
    else:
        total = sum(sizes)
        if total % R:
            stacked = jax.tree.map(lambda *ls: jnp.concatenate(ls),
                                   *[t for t, _ in blocks])
            blur = jnp.concatenate([b for _, b in blocks])
            with pytest.raises(ValueError, match="divisible"):
                sharded_hierarchical(stacked, blur, cohort_mesh(2, 4), R)


@SETTINGS
@given(seed=st.integers(0, 2**16), n=st.integers(1, 10),
       extra=st.integers(0, 9))
def test_pad_to_never_changes_weighted_sums(seed, n, extra):
    """CohortBatch.pad_to is invisible to every masked aggregation."""
    c = _cohort(seed, n, n)
    cfg = FLConfig(aggregator="flsimco")
    ref = AGGREGATORS["flsimco"](c, cfg)
    got = AGGREGATORS["flsimco"](c.pad_to(n + extra), cfg)
    _assert_trees_equal(ref, got)
