"""Aggregation schemes (Eq. 11 + baselines): unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as stst

from repro.core.aggregation import (aggregate_discard, aggregate_fedavg,
                                    aggregate_flsimco, flsimco_weights)


def _trees(key, n, shapes=((4, 3), (7,))):
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        out.append({"a": jax.random.normal(k, shapes[0]),
                    "b": {"c": jax.random.normal(jax.random.fold_in(k, 1),
                                                 shapes[1])}})
    return out


@settings(max_examples=50, deadline=None)
@given(levels=stst.lists(stst.floats(0.1, 50.0), min_size=2, max_size=16))
def test_flsimco_weights_normalized_and_ordered(levels):
    w = np.asarray(flsimco_weights(jnp.array(levels)))
    assert np.isclose(w.sum(), 1.0, atol=1e-5)
    assert (w >= -1e-7).all()
    # monotonicity: more blur -> strictly less weight (ties allowed)
    order_l = np.argsort(levels)
    assert (np.diff(w[order_l]) <= 1e-7).all()


def test_literal_eq11_weights_sum_to_n_minus_1():
    """DESIGN.md deviation #2: the unnormalized Eq. 11 weights sum to N-1."""
    L = jnp.array([1.0, 2.0, 3.0, 4.0])
    w = flsimco_weights(L, normalize=False)
    np.testing.assert_allclose(float(w.sum()), 3.0, rtol=1e-6)


def test_aggregate_identical_trees_is_identity():
    key = jax.random.PRNGKey(0)
    t = _trees(key, 1)[0]
    trees = [t] * 5
    out = aggregate_flsimco(trees, jnp.array([1.0, 2, 3, 4, 5]))
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_fedavg_equals_flsimco_with_equal_blur():
    key = jax.random.PRNGKey(1)
    trees = _trees(key, 4)
    fa = aggregate_fedavg(trees)
    fs = aggregate_flsimco(trees, jnp.ones(4) * 2.5)
    for l1, l2 in zip(jax.tree.leaves(fa), jax.tree.leaves(fs)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_discard_drops_fast_vehicles():
    from repro.core.mobility import BLUR_KMH_100, MobilityModel
    key = jax.random.PRNGKey(2)
    trees = _trees(key, 3)
    v = jnp.array([10.0, 50.0, 20.0])        # m/s; only idx 1 > 100 km/h
    blur = MobilityModel().blur_level(v)
    out = aggregate_discard(trees, blur, threshold=BLUR_KMH_100)
    expected = aggregate_fedavg([trees[0], trees[2]])
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(expected)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_disp_discard_thresholds_blur_not_velocity():
    """Regression: the registry documents "drop clients above
    cfg.blur_threshold" where the threshold is a BLUR level (Eq. 2), but
    the old dispatch thresholded raw velocities. Pin the kept-set under
    the blur semantics: v = [20, 30, 40] m/s with camera constant 0.58
    gives L = [11.6, 17.4, 23.2]; the default threshold (blur at
    100 km/h, ~16.11) keeps exactly client 0 — the velocity reading
    (v <= 27.78) would wrongly keep {0, 1}."""
    from repro.core.aggregation import AGGREGATORS, discard_weights
    from repro.core.cohort import CohortBatch
    from repro.core.mobility import BLUR_KMH_100, MobilityModel
    from repro.core.state import FLConfig

    cfg = FLConfig(aggregator="discard")
    assert np.isclose(cfg.blur_threshold, BLUR_KMH_100)
    v = jnp.array([20.0, 30.0, 40.0])
    blur = MobilityModel().blur_level(v)
    w = np.asarray(discard_weights(blur, cfg.blur_threshold))
    np.testing.assert_allclose(w, [1.0, 0.0, 0.0])   # the pinned kept-set

    key = jax.random.PRNGKey(5)
    trees = _trees(key, 3)
    cohort = CohortBatch.from_list(trees, jnp.zeros(3),
                                   velocities=v, blur=blur)
    out = AGGREGATORS["discard"](cohort, cfg)
    expected = trees[0]                               # only client 0 kept
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(expected)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   atol=1e-6)


def test_discard_all_fast_falls_back_to_fedavg():
    from repro.core.mobility import BLUR_KMH_100, MobilityModel
    key = jax.random.PRNGKey(3)
    trees = _trees(key, 3)
    blur = MobilityModel().blur_level(jnp.array([90.0, 80.0, 70.0]))
    out = aggregate_discard(trees, blur, BLUR_KMH_100)
    expected = aggregate_fedavg(trees)
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(expected)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=stst.integers(0, 2**31 - 1),
       levels=stst.lists(stst.floats(0.5, 30.0), min_size=2, max_size=6))
def test_aggregation_is_convex_combination(seed, levels):
    """Aggregated leaf values lie inside the per-client min/max envelope."""
    key = jax.random.PRNGKey(seed)
    trees = _trees(key, len(levels))
    out = aggregate_flsimco(trees, jnp.array(levels))
    stacked = [np.stack([np.asarray(l) for l in ls])
               for ls in zip(*[jax.tree.leaves(t) for t in trees])]
    for l_out, l_all in zip(jax.tree.leaves(out), stacked):
        assert (np.asarray(l_out) <= l_all.max(0) + 1e-5).all()
        assert (np.asarray(l_out) >= l_all.min(0) - 1e-5).all()


def test_beyond_paper_weightings_are_distributions():
    from repro.core.aggregation import inverse_weights, softmax_weights
    L = jnp.array([1.0, 5.0, 10.0, 20.0])
    for w in (softmax_weights(L), inverse_weights(L)):
        w = np.asarray(w)
        assert np.isclose(w.sum(), 1.0, atol=1e-5)
        assert (np.diff(w) <= 1e-7).all()   # more blur -> less weight
    # softmax penalizes the fast outlier harder than the linear scheme
    from repro.core.aggregation import flsimco_weights
    lin = np.asarray(flsimco_weights(L))
    sm = np.asarray(softmax_weights(L, temperature=2.0))
    assert sm[-1] < lin[-1]


def test_kernel_wagg_matches_tree_aggregation():
    from repro.kernels.ops import wagg_tree
    key = jax.random.PRNGKey(4)
    trees = _trees(key, 5)
    blur = jnp.array([1.0, 3.0, 2.0, 5.0, 4.0])
    w = flsimco_weights(blur)
    ref = aggregate_flsimco(trees, blur)
    out = wagg_tree(trees, w)
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
