"""repro.analysis acceptance tests: linter, contract checker, guards.

Three layers, mirroring the package:

  lint      seeded-violation snippets prove every rule class fires with
            the right rule id (>= 5 violations per class), and the
            suppression + baseline mechanics behave;
  contracts eval_shape catches deliberately broken registry entries —
            a wrong-treedef aggregator, a mask-dropping client update,
            a wrong-dtype weighting scheme — while the REAL registries
            check clean;
  guards    track_compiles sees fresh XLA compiles, assert_compile_bounds
            raises GuardViolation past the PR-6 campaign contract, and
            no_implicit_transfers trips on an implicit numpy upload.

Lint tests are pure stdlib (no jax execution); contract tests allocate
nothing (abstract interpretation only), so the whole file runs in
seconds.
"""
import textwrap
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import contracts, guards, lint
from repro.core.cohort import CohortBatch


def _rules(findings):
    return Counter(f.rule for f in findings)


def _lint(snippet):
    return lint.lint_source("snippet.py", textwrap.dedent(snippet))


# --------------------------------------------------------------------------
# lint: seeded violations, one block per rule class
# --------------------------------------------------------------------------

def test_lint_flags_host_syncs_in_hot_scope():
    findings = _lint("""\
        import jax
        import numpy as np

        def run_round(state, losses, x):
            a = float(losses[0])
            b = int(x.mean())
            c = jax.device_get(losses)
            jax.block_until_ready(x)
            d = losses.item()
            e = np.asarray(x)
            return a, b, c, d, e
    """)
    by_rule = _rules(findings)
    assert by_rule["host-sync-cast"] == 2
    assert by_rule["host-sync-fetch"] == 4
    assert sum(by_rule[r] for r in lint.HOST_SYNC_RULES) >= 5
    # findings carry location + a fix hint
    f = findings[0]
    assert f.path == "snippet.py" and f.line == 5 and f.hint


def test_lint_host_syncs_quiet_outside_hot_scope():
    """The same syncs in a cold helper are fine — hotness is scoped."""
    findings = _lint("""\
        import jax

        def summarize(losses, x):
            return float(losses[0]), jax.device_get(x)
    """)
    assert not findings


def test_lint_trivial_casts_not_flagged():
    """Shape metadata and host-side math are not device syncs."""
    findings = _lint("""\
        def run_round(x, cfg):
            a = int(x.shape[0])
            b = float(x.size)
            c = int(len(x))
            d = float(x.ndim + 1)
            return a, b, c, d
    """)
    assert not [f for f in findings if f.rule == "host-sync-cast"]


def test_lint_flags_retrace_hazards():
    findings = _lint("""\
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding

        def run_campaign(sc, spec):
            mesh = jax.make_mesh((2,), ("data",))
            sharding = NamedSharding(mesh, spec)
            fn = jax.jit(sc.step, static_argnums=[0])
            w = jnp.asarray([0.25, 0.75])
            z = jnp.full((4,), 0.5)
            return fn, sharding, w, z
    """)
    by_rule = _rules(findings)
    assert by_rule["retrace-ctor"] == 3            # make_mesh, NamedSharding, jit
    assert by_rule["retrace-static-unhashable"] == 1
    assert by_rule["retrace-fresh-array"] == 2
    assert sum(by_rule.values()) >= 5


def test_lint_retrace_quiet_under_lru_cache():
    """lru_cache'd construction is the sanctioned pattern, not a hazard."""
    findings = _lint("""\
        import functools
        import jax

        @functools.lru_cache(maxsize=None)
        def cohort_mesh(n):
            return jax.make_mesh((n,), ("data",))
    """)
    assert not [f for f in findings if f.rule == "retrace-ctor"]


def test_lint_flags_purity_violations():
    findings = _lint("""\
        import jax
        import numpy as np

        _CACHE = None

        def finalize(tree):
            global _CACHE
            key = jax.random.PRNGKey(0)
            ids = np.random.permutation(8)
            np.random.seed(0)
            v = np.random.rand(3)
            return key, ids, v
    """)
    by_rule = _rules(findings)
    assert by_rule["purity-global-mutation"] == 1
    assert by_rule["purity-fresh-prngkey"] == 1
    assert by_rule["purity-np-random"] == 3
    assert sum(by_rule.values()) >= 5
    # the packed-RandomState discipline is NOT flagged
    ok = _lint("""\
        import numpy as np

        def plan_round(host_rng):
            rs = np.random.RandomState(0)
            return rs.permutation(8)
    """)
    assert not [f for f in ok if f.rule == "purity-np-random"]


# --------------------------------------------------------------------------
# lint: suppression + baseline mechanics
# --------------------------------------------------------------------------

def test_suppression_inline_and_preceding_comment():
    findings = _lint("""\
        def run_round(losses, velocities, lr):
            a = float(losses[0])  # analysis: allow=host-sync-cast -- once/round
            # analysis: sanctioned-sync -- the designed per-round fetch
            b = (jax.device_get(velocities),
                 float(lr))
            return a, b
    """)
    assert not findings


def test_suppression_is_rule_specific():
    """allow= names exact rules; other rules on the line still fire."""
    findings = _lint("""\
        import jax.numpy as jnp

        def run_round(x):
            w = float(jnp.asarray(x).sum())  # analysis: allow=host-sync-cast
            return w
    """)
    assert _rules(findings) == {"retrace-fresh-array": 1}


def test_suppression_does_not_blanket_compound_bodies():
    """A comment directive covers the NEXT simple statement, not a whole
    loop body below it."""
    findings = _lint("""\
        def run_round(losses):
            # analysis: sanctioned-sync -- only the first line below
            for i in range(3):
                a = float(losses[i])
            return a
    """)
    assert _rules(findings) == {"host-sync-cast": 1}


def test_baseline_accepts_first_n_then_reports_extras(tmp_path):
    snippet = """\
        def run_round(losses):
            return float(losses[0])
    """
    old = _lint(snippet)
    path = str(tmp_path / "baseline.json")
    lint.save_baseline(old, path)
    baseline = lint.load_baseline(path)
    # unchanged code: fully absorbed
    assert lint.apply_baseline(_lint(snippet), baseline) == []
    # a new finding with a new fingerprint survives the baseline
    grown = _lint("""\
        def run_round(losses):
            return float(losses[0]), float(losses[1])
    """)
    fresh = lint.apply_baseline(grown, baseline)
    # the reworked line is a NEW fingerprint: both casts on it report
    assert len(fresh) == 2 and all(
        f.code == "return float(losses[0]), float(losses[1])" for f in fresh)
    # fingerprints are line-number free: shifting the finding is a no-op
    shifted = _lint("""\
        import os

        def run_round(losses):
            return float(losses[0])
    """)
    assert lint.apply_baseline(shifted, baseline) == []


def test_lint_cli_zero_against_committed_baseline(capsys, monkeypatch):
    """The CI invocation: repo sources lint clean vs analysis/baseline.json."""
    import os
    monkeypatch.chdir(os.path.dirname(os.path.dirname(__file__)))
    rc = lint.main(["src", "benchmarks", "examples"])
    out = capsys.readouterr().out
    assert rc == 0, out
    assert "0 finding(s)" in out


# --------------------------------------------------------------------------
# contracts: the real registries check clean
# --------------------------------------------------------------------------

def test_real_registries_pass_contracts():
    violations = contracts.check_all()
    assert violations == [], "\n".join(map(str, violations))


# --------------------------------------------------------------------------
# contracts: broken aggregators -> contract-treedef
# --------------------------------------------------------------------------

def _good_agg(cohort, cfg):
    w = cohort.mask / jnp.maximum(cohort.mask.sum(), 1.0)
    return jax.tree.map(
        lambda l: jnp.tensordot(w, l, axes=1), cohort.trees)


BROKEN_AGGREGATORS = {
    "wrapped-structure": lambda c, cfg: {"tree": _good_agg(c, cfg)},
    "reduced-shape": lambda c, cfg: jax.tree.map(
        lambda l: l.sum(axis=-1), _good_agg(c, cfg)),
    "cast-dtype": lambda c, cfg: jax.tree.map(
        lambda l: l.astype(jnp.float16), _good_agg(c, cfg)),
    "stacked-passthrough": lambda c, cfg: c.trees,
    "scalar": lambda c, cfg: jnp.zeros(()),
}


def test_broken_aggregators_flagged_with_treedef_rule():
    violations = contracts.check_aggregators(BROKEN_AGGREGATORS)
    assert len(violations) == len(BROKEN_AGGREGATORS) >= 5
    assert {v.entry for v in violations} == set(BROKEN_AGGREGATORS)
    assert all(v.rule == contracts.RULE_TREEDEF for v in violations)
    assert all(v.registry == "AGGREGATORS" for v in violations)
    # and the sane reference passes
    assert contracts.check_aggregators({"good": _good_agg}) == []


# --------------------------------------------------------------------------
# contracts: broken client updates -> contract-mask
# --------------------------------------------------------------------------

class _FakeClient:
    """Minimal CLIENT_UPDATES-shaped entry: echoes the global tree per
    row. `variant` seeds one specific contract violation."""

    def __init__(self, variant="good"):
        self.variant = variant

    def init_state(self, cfg, tree):
        return None

    def run_cohort(self, cfg, tree, client_state, batches, keys, lr,
                   parallel=True, pad_to=None, mesh=None):
        n = batches.shape[0]
        stacked = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape), tree)
        vec = jnp.zeros((n,), jnp.float32)
        mask = jnp.ones((n,), jnp.float32)
        v = self.variant
        if v == "plain-tree":
            return stacked, None               # no CohortBatch at all
        if v == "mask-none":
            mask = None
        elif v == "mask-shape":
            mask = jnp.ones((n + 1,), jnp.float32)
        elif v == "mask-dtype":
            mask = jnp.ones((n,), jnp.int32)
        count = n - 1 if v == "wrong-n" else n
        return CohortBatch(trees=stacked, losses=vec, mask=mask,
                           n=count, velocities=vec, blur=vec), None


BROKEN_CLIENTS = ("plain-tree", "mask-none", "mask-shape", "mask-dtype",
                  "wrong-n")


def test_broken_client_updates_flagged_with_mask_rule():
    broken = {v: _FakeClient(v) for v in BROKEN_CLIENTS}
    violations = contracts.check_client_updates(broken)
    assert len(BROKEN_CLIENTS) >= 5
    by_entry = {v.entry: v for v in violations}
    assert set(by_entry) == set(BROKEN_CLIENTS)
    assert all(v.rule == contracts.RULE_MASK for v in violations)
    assert all(v.registry == "CLIENT_UPDATES" for v in violations)
    # the well-formed variant passes the same checker
    assert contracts.check_client_updates({"good": _FakeClient()}) == []


# --------------------------------------------------------------------------
# contracts: broken weighting schemes -> contract-weight-*
# --------------------------------------------------------------------------

def test_scheme_weight_dtype_mismatch_flagged():
    violations = contracts.check_scheme_weights(
        {"int-weights": lambda c, cfg: jnp.ones((c.n,), jnp.int32)})
    assert [v.rule for v in violations] == [contracts.RULE_WEIGHT_DTYPE]


def test_scheme_padded_row_leak_flagged_with_hint():
    """Weights over the padded axis (m,) instead of the valid prefix
    (n,): the classic CohortBatch bug, flagged with a targeted hint."""
    violations = contracts.check_scheme_weights(
        {"padded": lambda c, cfg: c.mask / c.mask.sum()})
    assert violations and violations[0].rule == contracts.RULE_WEIGHT_SHAPE
    assert "padded rows" in violations[0].message


# --------------------------------------------------------------------------
# contracts: broken comms codecs -> contract-codec
# --------------------------------------------------------------------------

def _fake_codec(**over):
    from repro.comms.codecs import Codec
    kw = dict(name="fake", lossless=True, stateful=False,
              encode=lambda s, b, ef=None, stacked_base=False:
              ({"trees": s}, None),
              decode=lambda p, b, stacked_base=False: p["trees"],
              init_state=lambda cfg, tree: None)
    kw.update(over)
    return Codec(**kw)


BROKEN_CODECS = {
    # decode loses the dtype: aggregation would run on f16 trees
    "cast-dtype": _fake_codec(decode=lambda p, b, stacked_base=False:
                              jax.tree.map(lambda l: l.astype(jnp.float16),
                                           p["trees"])),
    # decode collapses the cohort axis
    "row-collapse": _fake_codec(decode=lambda p, b, stacked_base=False:
                                jax.tree.map(lambda l: l[:1], p["trees"])),
    # a stateless codec smuggling cross-round state out of encode
    "stateless-ef": _fake_codec(encode=lambda s, b, ef=None,
                                stacked_base=False:
                                ({"trees": s}, jnp.zeros((1, 8)))),
    # a stateful codec that shrinks the residual it was handed
    "ef-shrink": _fake_codec(
        stateful=True,
        init_state=lambda cfg, tree: {"ef": jnp.zeros(
            (cfg.vehicles_per_round, 256), jnp.float32)},
        encode=lambda s, b, ef=None, stacked_base=False:
        ({"trees": s}, ef[:1])),
}


def test_broken_codecs_flagged_with_codec_rule():
    violations = contracts.check_codecs(BROKEN_CODECS)
    by_entry = {v.entry: v for v in violations}
    assert set(by_entry) == set(BROKEN_CODECS)
    assert all(v.rule == contracts.RULE_CODEC for v in violations)
    assert all(v.registry == "CODECS" for v in violations)
    # and the well-formed passthrough passes the same checker
    assert contracts.check_codecs({"good": _fake_codec()}) == []


BROKEN_SERVE_CODECS = {
    # decode strips a SECOND axis: a vehicle would reconstruct the wrong
    # tree shape from the snapshot payload
    "axis-collapse": _fake_codec(decode=lambda p, b, stacked_base=False:
                                 jax.tree.map(lambda l: l[0], p["trees"])),
    # encode yields nothing to put on the wire; decode re-grows the axis
    # from the base so the roundtrip alone would look fine
    "empty-payload": _fake_codec(
        encode=lambda s, b, ef=None, stacked_base=False: ({}, None),
        decode=lambda p, b, stacked_base=False:
        jax.tree.map(lambda l: l[None], b)),
}


def test_broken_snapshot_framing_flagged_with_serve_rule():
    violations = contracts.check_serve(BROKEN_SERVE_CODECS)
    by_entry = {v.entry: v for v in violations}
    assert set(by_entry) == set(BROKEN_SERVE_CODECS)
    assert all(v.rule == contracts.RULE_SERVE for v in violations)
    assert all(v.registry == "CODECS" for v in violations)
    # the well-formed passthrough frames snapshots correctly
    assert contracts.check_serve({"good": _fake_codec()}) == []


def test_real_codecs_pass_serve_contract():
    assert contracts.check_serve() == []


def test_scheme_crash_reported_not_raised():
    violations = contracts.check_scheme_weights(
        {"boom": lambda c, cfg: (_ for _ in ()).throw(ValueError("boom"))})
    assert [v.rule for v in violations] == [contracts.RULE_EVAL_ERROR]


# --------------------------------------------------------------------------
# contracts: topology registry API
# --------------------------------------------------------------------------

def test_topology_api_violations_flagged():
    class NoSignature:
        name = "nosig"

        def init_topo_state(self, scenario):
            return {}

        def plan_round(self, state, scenario, rng):
            return {}

    violations = contracts.check_topologies({"nosig": NoSignature})
    assert violations
    assert all(v.rule == contracts.RULE_TOPOLOGY_API for v in violations)


# --------------------------------------------------------------------------
# guards
# --------------------------------------------------------------------------

def test_track_compiles_counts_fresh_compile():
    x = jnp.arange(4.0)

    @jax.jit
    def fresh(v):
        return v * 2.0 + 1.0

    with guards.track_compiles() as tracker:
        fresh(x).block_until_ready()
    assert tracker.backend_compiles >= 1
    with guards.track_compiles() as tracker:
        fresh(x).block_until_ready()       # cached: steady state
    assert tracker.backend_compiles == 0


def test_assert_compile_bounds_enforces_engine_contract():
    guards.assert_compile_bounds({"jit_round": 1, "scan": 2})
    guards.assert_compile_bounds({"jit_round": 0, "unbounded_extra": 99})
    with pytest.raises(guards.GuardViolation, match="jit_round=2 > 1"):
        guards.assert_compile_bounds({"jit_round": 2}, what="test")
    with pytest.raises(guards.GuardViolation, match="steady_state=1 > 0"):
        guards.assert_compile_bounds({"steady_state": 1},
                                     {"steady_state": 0})
    # the contract has exactly one home
    assert guards.ENGINE_COMPILE_BOUNDS == {"jit_round": 1, "scan": 2}


def test_no_implicit_transfers_blocks_numpy_leak():
    f = jax.jit(lambda v: v + 1.0)
    host = np.ones(3, np.float32)
    f(jnp.asarray(host)).block_until_ready()   # warm OUTSIDE the guard
    with guards.no_implicit_transfers():
        dev = jax.device_put(host)             # explicit: allowed
        f(dev).block_until_ready()
    with pytest.raises(Exception, match="[Dd]isallow"):
        with guards.no_implicit_transfers():
            f(host)                            # implicit upload: raises
