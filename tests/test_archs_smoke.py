"""Per-architecture smoke tests (assignment requirement (f)).

Each of the 10 assigned architectures is instantiated as its REDUCED
variant (2 layers, d_model<=256, <=4 experts — same family code path) and
runs one forward + one train step on CPU, asserting output shapes and the
absence of NaNs. Decode-vs-full equivalence is covered for every family.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES, InputShape, get_config, list_configs
from repro.models import transformer as T

ARCHS = [a for a in list_configs() if a != "resnet18-cifar"]


def _aux(cfg, key, B, S=None, dtype=jnp.float32):
    if cfg.family == "vlm":
        return {"patches": jax.random.normal(
            key, (B, cfg.n_vision_tokens, cfg.d_vision), dtype)}
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, 8, cfg.d_audio), dtype)}
    return None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, cache, aux = T.forward(cfg, params, toks,
                                   aux_inputs=_aux(cfg, key, B))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    # padded-vocab ids masked out
    if cfg.padded_vocab != cfg.vocab_size:
        assert float(logits[..., cfg.vocab_size:].max()) < -1e29


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_reduces_loss_or_stays_finite(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = T.init_params(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    aux_in = _aux(cfg, key, B)

    def loss_fn(p):
        logits, _, aux = T.forward(cfg, p, toks, aux_inputs=aux_in)
        lp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
        nll = -jnp.take_along_axis(lp, toks[:, 1:, None], -1).mean()
        return nll + aux

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    params2 = jax.tree.map(lambda p, g: p - 0.05 * g.astype(p.dtype),
                           params, grads)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0)  # one step on the same batch must descend


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)  # no drops
    key = jax.random.PRNGKey(2)
    params = T.init_params(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    ctx_len = 8 if cfg.family == "audio" else 0
    aux_in = _aux(cfg, key, B)
    full, _, _ = T.forward(cfg, params, toks, aux_inputs=aux_in)
    cache = T.init_cache(cfg, B, S + 1, dtype=jnp.float32, ctx_len=ctx_len)
    _, cache, _ = T.forward(cfg, params, toks[:, :S], mode="prefill",
                            cache=cache, aux_inputs=aux_in)
    dec, _, _ = T.forward(cfg, params, toks[:, S:S + 1], mode="decode",
                          cache=cache,
                          positions=jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(
        np.asarray(dec[:, 0, :cfg.vocab_size]),
        np.asarray(full[:, -1, :cfg.vocab_size]), atol=2e-4)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "rwkv6-1.6b", "hymba-1.5b"])
def test_sliding_window_decode_long_context(arch):
    """long_500k path (miniature): decode beyond the ring-buffer width
    stays finite and the buffer never grows."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(3)
    params = T.init_params(cfg, key)
    B = 2
    W = T.cache_width(cfg, 256, True)
    cache = T.init_cache(cfg, B, 256, dtype=jnp.float32, long_context=True)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    for pos in [0, 1, W // 2, W, W + 3, 2 * W + 1]:
        logits, cache, _ = T.forward(cfg, params, tok, mode="decode",
                                     cache=cache,
                                     positions=jnp.full((B,), pos, jnp.int32),
                                     long_context=True)
        assert bool(jnp.isfinite(logits[..., :cfg.vocab_size]).all())
    if "kv" in cache:
        assert cache["kv"]["k"].shape[2] == W  # ring buffer fixed width


def test_full_configs_match_assignment_table():
    """The exact hyper-parameters from the assignment block."""
    t = get_config("tinyllama-1.1b")
    assert (t.n_layers, t.d_model, t.n_heads, t.n_kv_heads, t.d_ff,
            t.vocab_size) == (22, 2048, 32, 4, 5632, 32000)
    g = get_config("gemma2-27b")
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab_size) == (46, 4608, 32, 16, 36864, 256000)
    kk = get_config("kimi-k2-1t-a32b")
    assert (kk.n_layers, kk.d_model, kk.n_heads, kk.n_kv_heads, kk.d_ff,
            kk.vocab_size, kk.n_experts, kk.n_experts_active) == \
        (61, 7168, 64, 8, 2048, 163840, 384, 8)
    o = get_config("olmoe-1b-7b")
    assert (o.n_layers, o.d_model, o.n_experts, o.n_experts_active) == \
        (16, 2048, 64, 8)
    q = get_config("qwen2-0.5b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab_size, q.qkv_bias) == (24, 896, 14, 2, 4864, 151936, True)
    d = get_config("deepseek-67b")
    assert (d.n_layers, d.d_model, d.n_heads, d.n_kv_heads, d.d_ff,
            d.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    r = get_config("rwkv6-1.6b")
    assert (r.n_layers, r.d_model, r.d_ff, r.vocab_size) == \
        (24, 2048, 7168, 65536)
    h = get_config("hymba-1.5b")
    assert (h.n_layers, h.d_model, h.n_heads, h.n_kv_heads, h.d_ff,
            h.vocab_size, h.ssm_state) == (32, 1600, 25, 5, 5504, 32001, 16)
    s = get_config("seamless-m4t-large-v2")
    assert (s.n_layers, s.d_model, s.n_heads, s.d_ff, s.vocab_size) == \
        (24, 1024, 16, 8192, 256206)
    v = get_config("llama-3.2-vision-90b")
    assert (v.n_layers, v.d_model, v.n_heads, v.n_kv_heads, v.d_ff,
            v.vocab_size) == (100, 8192, 64, 8, 28672, 128256)
    # parameter-count sanity: ~1T total / ~32B active for kimi
    assert 0.9e12 < kk.n_params() < 1.3e12
    assert 20e9 < kk.n_active_params() < 45e9
    assert 60e9 < d.n_params() < 75e9


def test_reduced_configs_are_small():
    for a in ARCHS:
        r = get_config(a).reduced()
        assert r.n_layers <= 2 and r.d_model <= 512
        if r.n_experts:
            assert r.n_experts <= 4
