"""Checkpoint round-trip tests: legacy `like`-based restore, structural
(no-example-tree) restore, and full-FLState payloads."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import (latest, restore, restore_state, save,
                                    save_state)


def test_roundtrip(tmp_path):
    key = jax.random.PRNGKey(0)
    tree = {"params": {"w": jax.random.normal(key, (4, 5)),
                       "b": jnp.zeros((5,), jnp.bfloat16)},
            "opt": [jnp.ones((3,)), {"count": jnp.int32(7)}]}
    path = os.path.join(tmp_path, "ckpt_10.npz")
    save(path, 10, tree)
    step, restored = restore(path, tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_latest_pointer(tmp_path):
    tree = {"x": jnp.arange(3)}
    save(os.path.join(tmp_path, "c1.npz"), 1, tree)
    save(os.path.join(tmp_path, "c2.npz"), 2, tree)
    path, step = latest(str(tmp_path))
    assert step == 2 and path.endswith("c2.npz")


def test_shape_mismatch_raises(tmp_path):
    tree = {"x": jnp.zeros((3,))}
    p = os.path.join(tmp_path, "c.npz")
    save(p, 0, tree)
    with pytest.raises(ValueError):
        restore(p, {"x": jnp.zeros((4,))})


def test_structural_restore_needs_no_example_tree(tmp_path):
    """The stored spec rebuilds dict/list/tuple/None nesting exactly —
    including bfloat16 leaves and exact int64/float64 scalars."""
    tree = {"params": {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 5)),
                       "b": (jnp.full((3,), 2.5, jnp.bfloat16),
                             jnp.int32(7))},
            "none_field": None,
            "counters": [np.int64(2**40 + 3), np.float64(1e-300)]}
    p = os.path.join(tmp_path, "structural.npz")
    save(p, 4, tree)
    step, restored = restore(p)          # <- no `like`
    assert step == 4
    assert isinstance(restored, dict)
    assert isinstance(restored["params"]["b"], tuple)
    assert restored["none_field"] is None
    assert isinstance(restored["counters"], list)
    assert restored["params"]["b"][0].dtype == jnp.bfloat16
    # int64/float64 survive exactly (no x32 narrowing)
    assert int(restored["counters"][0]) == 2**40 + 3
    assert float(restored["counters"][1]) == 1e-300
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flstate_roundtrip_with_bf16_and_fedco_queue(tmp_path):
    """A full FLState payload — bf16 model leaves, FedCo key-tree + queue,
    host RNG, round counter — round-trips structurally."""
    from repro.core.scenario import Scenario

    sc = Scenario(client="fedco", aggregator="fedavg", partitioner="iid",
                  n_per_class=10, n_vehicles=4, vehicles_per_round=2,
                  batch_size=4, rounds=2, queue_len=32, seed=9)
    state = sc.init_state()
    # exercise the raw-bits path on a model leaf too
    tree = dict(state.global_tree)
    tree["extra_bf16"] = jnp.arange(6, dtype=jnp.bfloat16)
    state = state.replace(global_tree=tree)

    p = save_state(os.path.join(tmp_path, "flstate.npz"), state)
    restored = restore_state(p)
    assert restored.round == state.round == 0
    assert restored.global_tree["extra_bf16"].dtype == jnp.bfloat16
    assert set(restored.client_state) == {"key_tree", "queue"}
    np.testing.assert_array_equal(np.asarray(restored.client_state["queue"]),
                                  np.asarray(state.client_state["queue"]))
    for a, b in zip(jax.tree.leaves(state.to_tree()),
                    jax.tree.leaves(restored.to_tree())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_state_rejects_mismatched_scenario(tmp_path):
    """A checkpoint stamped with one experiment's fingerprint refuses to
    resume under a different client/aggregator/topology."""
    import jax.random as jr

    from repro.core.scenario import Scenario
    from repro.core.state import FLState, pack_host_rng

    sc_a = Scenario(partitioner="iid", n_vehicles=4, vehicles_per_round=2,
                    batch_size=4, rounds=2, seed=0)
    state = FLState(global_tree={"w": jnp.zeros((2,))}, key=jr.PRNGKey(0),
                    host_rng=pack_host_rng(np.random.RandomState(0)))
    p = save_state(os.path.join(tmp_path, "fp.npz"), state, scenario=sc_a)
    # same scenario: fine
    restore_state(p, scenario=sc_a)
    # different aggregator: loud failure naming the field
    sc_b = Scenario(aggregator="fedavg", partitioner="iid", n_vehicles=4,
                    vehicles_per_round=2, batch_size=4, rounds=2, seed=0)
    with pytest.raises(ValueError, match="aggregator"):
        restore_state(p, scenario=sc_b)
    # no scenario / no sidecar: check is skipped
    restore_state(p)
    p2 = save_state(os.path.join(tmp_path, "nofp.npz"), state)
    restore_state(p2, scenario=sc_b)


def test_restore_without_spec_requires_like(tmp_path):
    """Checkpoints written before structural specs still restore with an
    example tree; without one the error is actionable."""
    tree = {"x": jnp.arange(4)}
    p = os.path.join(tmp_path, "old.npz")
    save(p, 1, tree)
    # simulate a pre-spec checkpoint by stripping __spec__
    z = dict(np.load(p))
    z.pop("__spec__")
    np.savez(p, **z)
    with pytest.raises(ValueError, match="structural"):
        restore(p)
    step, restored = restore(p, tree)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(4))
