"""Checkpoint round-trip tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import latest, restore, save


def test_roundtrip(tmp_path):
    key = jax.random.PRNGKey(0)
    tree = {"params": {"w": jax.random.normal(key, (4, 5)),
                       "b": jnp.zeros((5,), jnp.bfloat16)},
            "opt": [jnp.ones((3,)), {"count": jnp.int32(7)}]}
    path = os.path.join(tmp_path, "ckpt_10.npz")
    save(path, 10, tree)
    step, restored = restore(path, tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert restored["params"]["b"].dtype == jnp.bfloat16


def test_latest_pointer(tmp_path):
    tree = {"x": jnp.arange(3)}
    save(os.path.join(tmp_path, "c1.npz"), 1, tree)
    save(os.path.join(tmp_path, "c2.npz"), 2, tree)
    path, step = latest(str(tmp_path))
    assert step == 2 and path.endswith("c2.npz")


def test_shape_mismatch_raises(tmp_path):
    tree = {"x": jnp.zeros((3,))}
    p = os.path.join(tmp_path, "c.npz")
    save(p, 0, tree)
    import pytest
    with pytest.raises(ValueError):
        restore(p, {"x": jnp.zeros((4,))})
