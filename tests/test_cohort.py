"""CohortBatch: padded/masked aggregation bit-exactness + invariants.

The load-bearing guarantee of the stacked-cohort round engine: a cohort
padded to a bucketed size (garbage-but-finite padding rows, zero masked
weights) aggregates BIT-EXACTLY like the unpadded cohort, for every
entry in ``AGGREGATORS`` and on both weighted-sum backends (jnp tree-map
and the Pallas wagg kernel in interpret mode). Weights are computed on
the static valid slice and zero-padded, so padding adds exact +0.0 terms
to the reduction — see core/cohort.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation as agg
from repro.core.aggregation import AGGREGATORS
from repro.core.cohort import CohortBatch, bucket_size
from repro.core.state import FLConfig


def _stacked_trees(key, m, shapes=((4, 3), (7,))):
    return {"a": jax.random.normal(key, (m,) + shapes[0]),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1),
                                         (m,) + shapes[1])}}


def _cohort(key, n, m, blur):
    """n valid clients padded to m rows; padding rows are random garbage
    (finite) to prove the mask really excludes them."""
    trees = _stacked_trees(key, m)
    losses = jax.random.uniform(jax.random.fold_in(key, 2), (m,))
    blur_pad = jnp.concatenate(
        [jnp.asarray(blur, jnp.float32),
         jnp.full((m - n,), 99.0, jnp.float32)])  # garbage padding blur
    return CohortBatch.from_stacked(trees, losses, n=n, blur=blur_pad)


# blur values chosen to straddle the default FLConfig.blur_threshold
# (~16.11) so "discard" keeps a strict subset
BLUR = jnp.array([11.6, 17.4, 12.8])


@pytest.mark.parametrize("backend", ["tree", "interpret"])
@pytest.mark.parametrize("name", sorted(AGGREGATORS))
def test_padded_aggregation_bit_exact_vs_unpadded(name, backend):
    key = jax.random.PRNGKey(0)
    cfg = FLConfig(aggregator=name)
    padded = _cohort(key, n=3, m=8, blur=BLUR)
    unpadded = CohortBatch.from_stacked(padded.valid_trees,
                                        padded.valid_losses, n=3, blur=BLUR)
    with agg.wagg_backend(backend):
        out_p = AGGREGATORS[name](padded, cfg)
        out_u = AGGREGATORS[name](unpadded, cfg)
    for lp, lu in zip(jax.tree.leaves(out_p), jax.tree.leaves(out_u)):
        np.testing.assert_array_equal(np.asarray(lp), np.asarray(lu))


def test_masked_kernel_matches_prezeroed_weights():
    """wagg_stacked(mask=...) == wagg_stacked with weights zeroed ahead of
    time — the in-kernel mask multiply is exact."""
    from repro.kernels.ops import wagg_stacked
    key = jax.random.PRNGKey(1)
    stacked = _stacked_trees(key, 5)
    w = jnp.array([0.3, 0.2, 0.5, 0.7, 0.9])
    mask = jnp.array([1.0, 1.0, 1.0, 0.0, 0.0])
    out_m = wagg_stacked(stacked, w, mask=mask, interpret=True)
    out_z = wagg_stacked(stacked, w * mask, interpret=True)
    for lm, lz in zip(jax.tree.leaves(out_m), jax.tree.leaves(out_z)):
        np.testing.assert_array_equal(np.asarray(lm), np.asarray(lz))


def test_bucket_size_policy():
    assert [bucket_size(n) for n in (1, 2, 3, 4, 5, 8, 9)] == \
        [1, 2, 4, 4, 8, 8, 16]
    with pytest.raises(ValueError):
        bucket_size(0)
    # the bound the handover topology relies on: cohorts of any size
    # 1..V land in at most ceil(log2(V)) + 1 distinct buckets
    V = 8
    assert len({bucket_size(s) for s in range(1, V + 1)}) <= \
        int(np.ceil(np.log2(V))) + 1


def test_from_list_unstack_roundtrip():
    key = jax.random.PRNGKey(2)
    trees = [jax.tree.map(lambda x: x[i], _stacked_trees(key, 3))
             for i in range(3)]
    c = CohortBatch.from_list(trees, [jnp.asarray(0.1), jnp.asarray(0.2),
                                      jnp.asarray(0.3)])
    assert c.n == c.size == 3
    back = c.unstack()
    for t0, t1 in zip(trees, back):
        for l0, l1 in zip(jax.tree.leaves(t0), jax.tree.leaves(t1)):
            np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    np.testing.assert_allclose(np.asarray(c.valid_losses), [0.1, 0.2, 0.3])


def test_concat_drops_padding_and_take_gathers():
    key = jax.random.PRNGKey(3)
    c1 = _cohort(key, n=2, m=4, blur=jnp.array([1.0, 2.0]))
    c2 = _cohort(jax.random.fold_in(key, 5), n=3, m=4,
                 blur=jnp.array([3.0, 4.0, 5.0]))
    full = CohortBatch.concat([c1, c2])
    assert full.n == full.size == 5
    np.testing.assert_allclose(np.asarray(full.blur), [1, 2, 3, 4, 5])
    # row i of the concat is the i-th valid row of (c1 then c2)
    np.testing.assert_array_equal(np.asarray(full.trees["a"][2]),
                                  np.asarray(c2.trees["a"][0]))
    sub = full.take(np.array([4, 0]))
    assert sub.n == 2
    np.testing.assert_array_equal(np.asarray(sub.trees["a"][0]),
                                  np.asarray(c2.trees["a"][2]))
    np.testing.assert_allclose(np.asarray(sub.blur), [5.0, 1.0])


def test_padded_weights_and_stat_validation():
    key = jax.random.PRNGKey(4)
    c = _cohort(key, n=3, m=8, blur=BLUR)
    w = c.padded_weights(jnp.array([0.5, 0.25, 0.25]))
    assert w.shape == (8,)
    np.testing.assert_allclose(np.asarray(w[3:]), 0.0)
    with pytest.raises(ValueError, match="weights"):
        c.padded_weights(jnp.ones(5))
    with pytest.raises(ValueError, match="stat length"):
        c.with_stats(velocities=jnp.ones(5))
    # incremental attachment: adding velocities must not wipe blur
    c2 = c.with_stats(velocities=jnp.ones(3))
    np.testing.assert_array_equal(np.asarray(c2.blur), np.asarray(c.blur))
    assert c2.velocities.shape == (8,)
    with pytest.raises(ValueError, match="valid count"):
        CohortBatch.from_stacked(c.trees, c.losses, n=9)
    plain = CohortBatch.from_stacked(c.trees, c.losses, n=3)
    with pytest.raises(ValueError, match="blur"):
        _ = plain.valid_blur


def test_cohort_is_a_pytree():
    """device_get fetches the whole record payload in one transfer."""
    key = jax.random.PRNGKey(5)
    c = _cohort(key, n=3, m=4, blur=BLUR)
    fetched = jax.device_get(c)
    assert isinstance(fetched, CohortBatch)
    assert fetched.n == 3
    assert isinstance(fetched.losses, np.ndarray)


@pytest.mark.parametrize("name", sorted(AGGREGATORS))
def test_pad_to_aggregation_bit_exact(name):
    """pad_to re-padding (the shard() pre-step) is invisible to every
    masked aggregation, exactly like the original bucket padding."""
    key = jax.random.PRNGKey(6)
    cfg = FLConfig(aggregator=name)
    c = _cohort(key, n=3, m=4, blur=BLUR)
    out = AGGREGATORS[name](c, cfg)
    out_p = AGGREGATORS[name](c.pad_to(16), cfg)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(out_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pad_to_replicates_last_row_and_keeps_mask():
    key = jax.random.PRNGKey(7)
    c = _cohort(key, n=2, m=4, blur=jnp.array([11.0, 13.0]))
    p = c.pad_to(8)
    assert p.n == 2 and p.size == 8
    np.testing.assert_array_equal(np.asarray(p.mask),
                                  [1, 1, 0, 0, 0, 0, 0, 0])
    # new rows replicate row m-1 of every leaf (finite, no RNG)
    for leaf in jax.tree.leaves(p.trees):
        for i in range(4, 8):
            np.testing.assert_array_equal(np.asarray(leaf[i]),
                                          np.asarray(leaf[3]))
    np.testing.assert_array_equal(np.asarray(p.blur[4:]),
                                  np.full(4, np.asarray(c.blur[3])))
    # valid views are untouched
    np.testing.assert_array_equal(np.asarray(p.valid_losses),
                                  np.asarray(c.valid_losses))
    with pytest.raises(ValueError, match="smaller"):
        p.pad_to(4)
    assert p.pad_to(8) is p  # no-op fast path


def test_shard_gather_roundtrip_single_device():
    """shard()/gather() on the trivial one-device mesh: values bitwise
    untouched, size padded to a multiple of the mesh extent. (Real
    multi-device placement is covered in tests/multidevice/.)"""
    from repro.launch.mesh import cohort_mesh
    key = jax.random.PRNGKey(8)
    c = _cohort(key, n=3, m=4, blur=BLUR)
    mesh = cohort_mesh(1, 1)
    s = c.shard(mesh)
    assert s.size == 4 and s.n == 3
    spec = CohortBatch.sharding_spec(mesh)
    assert s.losses.sharding.is_equivalent_to(spec, s.losses.ndim)
    g = s.gather()
    for a, b in zip(jax.tree.leaves(c), jax.tree.leaves(g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
