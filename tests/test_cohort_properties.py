"""Property tests for `CohortBatch` padding/masking invariants (tier-1).

Hypothesis-driven extension of the deterministic padded-vs-unpadded
suite in tests/test_cohort.py: random valid counts, padded sizes and
schemes, always the same invariant — padding is invisible to every
masked aggregation, `pad_to` composes, and the valid views never see a
padding row. hypothesis is a dev-only dependency (requirements-dev.txt);
the module skips when absent, like tests/test_aggregation.py. The
sharded counterparts (same invariants under a real mesh) live in
tests/multidevice/test_sharded_properties.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.aggregation import AGGREGATORS
from repro.core.cohort import CohortBatch, bucket_size
from repro.core.state import FLConfig

SETTINGS = settings(max_examples=40, deadline=None)


def _cohort(seed, n, m):
    key = jax.random.PRNGKey(seed)
    trees = {"a": jax.random.normal(key, (m, 3, 2)),
             "b": jax.random.normal(jax.random.fold_in(key, 1), (m, 5))}
    blur = jax.random.uniform(jax.random.fold_in(key, 2), (n,),
                              minval=10.0, maxval=20.0)
    blur_pad = jnp.concatenate([blur, jnp.full((m - n,), 99.0)])
    losses = jax.random.uniform(jax.random.fold_in(key, 3), (m,))
    return CohortBatch.from_stacked(trees, losses, n=n, blur=blur_pad)


def _assert_trees_equal(t1, t2):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@SETTINGS
@given(seed=st.integers(0, 2**16), n=st.integers(1, 10),
       pad=st.integers(0, 8),
       scheme=st.sampled_from(sorted(AGGREGATORS)))
def test_padding_is_invisible_to_every_scheme(seed, n, pad, scheme):
    c = _cohort(seed, n, n + pad)
    unpadded = CohortBatch.from_stacked(c.valid_trees, c.valid_losses,
                                        n=n, blur=c.valid_blur)
    cfg = FLConfig(aggregator=scheme)
    _assert_trees_equal(AGGREGATORS[scheme](c, cfg),
                        AGGREGATORS[scheme](unpadded, cfg))


@SETTINGS
@given(seed=st.integers(0, 2**16), n=st.integers(1, 8),
       extra1=st.integers(0, 5), extra2=st.integers(0, 5))
def test_pad_to_composes_and_preserves_views(seed, n, extra1, extra2):
    """pad_to(a).pad_to(a+b) == pad_to(a+b) on every observable: valid
    views, masks, and any masked weighted sum."""
    c = _cohort(seed, n, n)
    once = c.pad_to(n + extra1 + extra2)
    twice = c.pad_to(n + extra1).pad_to(n + extra1 + extra2)
    assert once.n == twice.n == n
    np.testing.assert_array_equal(np.asarray(once.mask),
                                  np.asarray(twice.mask))
    _assert_trees_equal(once.valid_trees, twice.valid_trees)
    np.testing.assert_array_equal(np.asarray(once.valid_losses),
                                  np.asarray(twice.valid_losses))
    cfg = FLConfig(aggregator="flsimco")
    _assert_trees_equal(AGGREGATORS["flsimco"](once, cfg),
                        AGGREGATORS["flsimco"](twice, cfg))
    with pytest.raises(ValueError, match="smaller"):
        once.pad_to(once.size - 1)


@SETTINGS
@given(n=st.integers(1, 4096))
def test_bucket_size_is_minimal_power_of_two(n):
    b = bucket_size(n)
    assert b >= n and (b & (b - 1)) == 0
    assert b == 1 or b // 2 < n


@SETTINGS
@given(seed=st.integers(0, 2**16), n=st.integers(1, 8),
       pad=st.integers(1, 6))
def test_padded_weights_zero_exactly_the_padding(seed, n, pad):
    c = _cohort(seed, n, n + pad)
    w = jax.random.uniform(jax.random.PRNGKey(seed + 1), (n,))
    padded = c.padded_weights(w)
    np.testing.assert_array_equal(np.asarray(padded[:n]), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(padded[n:]),
                                  np.zeros(pad, np.float32))
