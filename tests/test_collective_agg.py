"""Mesh-level aggregation == host-level aggregation (DESIGN.md §2).

Three equivalences that justify the production mapping:

1. weighted psum over the federated axis (shard_map) == host
   aggregate_flsimco over the same client trees.
2. weighted-example-loss gradient == Eq.-11-weighted combination of
   per-cohort gradients (the identity the pjit train_step relies on).
3. one pjit train step with aggregation="flsimco" on a host mesh ==
   explicit per-cohort SGD + host aggregation (local_iters=1).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.aggregation import (aggregate_flsimco, flsimco_weights,
                                    normalized_weight_on_axis,
                                    weighted_psum_tree)

N_DEV = jax.device_count()


def test_weighted_psum_matches_host_aggregation():
    """Stacked client trees on a 1-axis mesh: psum-based Eq. 11 ==
    aggregate_flsimco. Runs on however many devices exist (1 on CI)."""
    n = N_DEV
    mesh = jax.make_mesh((n,), ("clients",))
    key = jax.random.PRNGKey(0)
    trees = [{"w": jax.random.normal(jax.random.fold_in(key, i), (4, 8)),
              "b": jax.random.normal(jax.random.fold_in(key, 100 + i), (8,))}
             for i in range(n)]
    blur = jnp.arange(1.0, n + 1.0)

    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *trees)

    def per_client(tree, L):
        w = normalized_weight_on_axis(L[0], "clients")
        agg = weighted_psum_tree(jax.tree.map(lambda x: x[0], tree), w,
                                 "clients")
        return agg

    fn = shard_map(per_client, mesh=mesh,
                   in_specs=(P("clients"), P("clients")),
                   out_specs=P())
    out = fn(stacked, blur)
    expected = aggregate_flsimco(trees, blur)
    for l1, l2 in zip(jax.tree.leaves(out), jax.tree.leaves(expected)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_weighted_example_loss_grad_equals_weighted_cohort_grads():
    """grad of sum_i w_i l_i(theta)  ==  sum_n w_n grad L_n(theta)."""
    key = jax.random.PRNGKey(1)
    d, n = 6, 4
    theta = jax.random.normal(key, (d,))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
    ys = jax.random.normal(jax.random.fold_in(key, 2), (n,))
    blur = jnp.array([1.0, 4.0, 2.0, 3.0])
    w = flsimco_weights(blur)

    def per_example_loss(theta, x, y):
        return (x @ theta - y) ** 2

    # weighted-loss gradient (production pjit form)
    g1 = jax.grad(lambda t: jnp.sum(
        w * jax.vmap(per_example_loss, (None, 0, 0))(t, xs, ys)))(theta)
    # per-cohort grads then Eq.-11 aggregation (paper's RSU form)
    cohort_grads = [jax.grad(lambda t: per_example_loss(t, xs[i], ys[i]))(theta)
                    for i in range(n)]
    g2 = sum(float(w[i]) * cohort_grads[i] for i in range(n))
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_pjit_train_step_equals_host_federated_round():
    """End-to-end: steps.make_train_step(aggregation='flsimco') on the host
    mesh produces the same updated params as explicit per-cohort SGD +
    host-level Eq. 11 aggregation (local_iters=1, no momentum carry)."""
    import dataclasses
    from repro import compat
    from repro.configs.base import get_config, InputShape
    from repro.launch import steps as st
    from repro.launch.mesh import make_host_mesh

    cfg = get_config("qwen2-0.5b").reduced()
    mesh = make_host_mesh()
    B, S = 4, 16
    shape = InputShape("test", S, B, "train")
    lr = 0.1
    fn, nm = st.make_train_step(cfg, shape, mesh, objective="lm", lr=lr,
                                momentum=0.9, weight_decay=0.0,
                                aggregation="flsimco", n_micro=1)
    key = jax.random.PRNGKey(3)
    from repro.models import transformer as T
    params = T.init_params(cfg, key)
    mom = st.init_momentum(params)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    blur = jnp.array([2.0, 8.0, 4.0, 6.0])
    with compat.set_mesh(mesh):
        new_p, _, metrics = jax.jit(fn)(params, mom, {"tokens": toks,
                                                      "blur": blur})

    # host-level: each example is a cohort; local SGD step then aggregate
    w = flsimco_weights(blur)

    def cohort_loss(p, tok):
        logits, _, aux = T.forward(cfg, p, tok[None])
        return st.lm_loss_per_example(cfg, logits, tok[None])[0] + aux

    client_params = []
    for i in range(B):
        g = jax.grad(cohort_loss)(params, toks[i])
        client_params.append(jax.tree.map(
            lambda p, gg: p - lr * gg.astype(p.dtype), params, g))
    # theta - lr * sum w_n g_n  ==  sum w_n (theta - lr g_n)
    expected = aggregate_flsimco(client_params, blur)
    for l1, l2 in zip(jax.tree.leaves(new_p), jax.tree.leaves(expected)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   atol=5e-4)
