"""Comms codec tier (comms/codecs.py) acceptance tests.

The contract layers pinned here:

  1. registry — `CODECS` mirrors the AGGREGATORS/CLIENT_UPDATES shape
     and `FLConfig` validates codec names at construction;
  2. lossless bit-exactness — `codec="delta"` yields BITWISE-identical
     campaigns to `codec="identity"` for all five SCHEME_WEIGHTS
     schemes on the eager host paths (single/multi/handover) AND inside
     the compiled engine (jit and scan modes), because the delta is a
     wrapping bitcast-integer difference and aggregation always runs on
     the reconstructed trees;
  3. stateful codecs — delta_int8's error-feedback residual lives in
     `FLState.comms`, threads through the engine carry with the compile
     bounds intact (jit_round <= 1, scan <= 2), survives checkpoint
     save/restore bit for bit, and keeps the within-mode determinism
     contract of tests/test_engine.py.

Cross-codec MODEL values for the lossy tier are only close in a
relative sense (and this micro payload diverges by design — lr=0.4 on
random 4x4 noise), so the int8 campaign tests assert mechanics (state
threading, determinism, byte accounting), not accuracy; the error BOUND
is pinned per-block in tests/test_comms_properties.py.
"""
import functools
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.store import restore_state, save_state
from repro.comms.codecs import (CODECS, comms_init_state, flat_width,
                                payload_nbytes, roundtrip_cohort,
                                tree_nbytes)
from repro.core.aggregation import SCHEME_WEIGHTS
from repro.core.engine import compile_counts, run_campaign
from repro.core.scenario import Scenario, run
from repro.core.state import FLConfig, FLState

_RS = np.random.RandomState(0)
DATA = [_RS.rand(6, 4, 4, 3).astype(np.float32) for _ in range(8)]

TINY = dict(data=DATA, n_vehicles=8, vehicles_per_round=3,
            batch_size=2, rounds=4, local_iters=1, lr=0.4, seed=11)

CASES = {
    "single": dict(topology="single"),
    "multi": dict(topology="multi", topology_kwargs={"n_rsus": 2}),
    "handover": dict(topology="handover",
                     topology_kwargs={"n_rsus": 2, "rsu_range": 200.0,
                                      "round_duration": 50.0,
                                      "sync_every": 2}),
}


def _scenario(case, **over):
    kw = {**TINY, **CASES[case]}
    kw.update(over)
    return Scenario(**kw)


def _assert_trees_equal(t1, t2):
    l1, l2 = jax.tree.leaves(t1), jax.tree.leaves(t2)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def _assert_states_identical(s1: FLState, s2: FLState):
    _assert_trees_equal(s1.to_tree(), s2.to_tree())
    assert s1.round == s2.round


# memoized reference campaigns — shared across the bitwise tests below
@functools.lru_cache(maxsize=None)
def _eager(case, codec):
    return run(_scenario(case, codec=codec), rounds=4)


@functools.lru_cache(maxsize=None)
def _jit(case, codec):
    return run_campaign(_scenario(case, codec=codec), rounds=4, mode="jit")


# --------------------------------------------------------------------------
# registry + config validation
# --------------------------------------------------------------------------

def test_registry_shape():
    assert set(CODECS) == {"identity", "delta", "delta_int8"}
    for name, c in CODECS.items():
        assert c.name == name
        assert callable(c.encode) and callable(c.decode)
    assert CODECS["identity"].lossless and not CODECS["identity"].stateful
    assert CODECS["delta"].lossless and not CODECS["delta"].stateful
    assert not CODECS["delta_int8"].lossless
    assert CODECS["delta_int8"].stateful


def test_config_rejects_unknown_codec():
    with pytest.raises(ValueError, match="codec"):
        FLConfig(codec="gzip")


def test_comms_init_state_shapes():
    tree = {"w": jnp.zeros((3, 5)), "b": jnp.zeros((7,))}
    cfg = FLConfig(vehicles_per_round=4)
    assert comms_init_state(cfg, tree) is None                  # identity
    assert comms_init_state(
        FLConfig(vehicles_per_round=4, codec="delta"), tree) is None
    st = comms_init_state(
        FLConfig(vehicles_per_round=4, codec="delta_int8"), tree)
    assert set(st) == {"ef"}
    assert st["ef"].shape == (4, flat_width(tree))
    assert flat_width(tree) == 256                              # 22 -> BQ


# --------------------------------------------------------------------------
# lossless bit-exactness: eager host paths, all five schemes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("scheme", sorted(SCHEME_WEIGHTS))
def test_delta_bitwise_all_schemes_single(scheme):
    """Acceptance: codec="delta" replays codec="identity" bit for bit
    under every weighting scheme — the reconstructed cohort IS the
    original cohort, so Eq. 2/Eq. 11 weighting never sees the codec."""
    st_i, hist_i = run(_scenario("single", aggregator=scheme), rounds=2)
    st_d, hist_d = run(_scenario("single", aggregator=scheme,
                                 codec="delta"), rounds=2)
    _assert_states_identical(st_i, st_d)
    assert hist_i == hist_d


@pytest.mark.parametrize("case", ["multi", "handover"])
def test_delta_bitwise_hierarchical_topologies(case):
    """Multi-RSU per-group roundtrips and handover per-download-RSU
    bases (stacked deltas against each RSU's model) stay lossless."""
    st_i, hist_i = _eager(case, "identity")
    st_d, hist_d = _eager(case, "delta")
    _assert_states_identical(st_i, st_d)
    assert hist_i == hist_d


# --------------------------------------------------------------------------
# lossless bit-exactness: compiled engine, both modes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_engine_delta_bitwise(case):
    """The codec stage traced into the fused round body changes nothing
    for the lossless tier: engine campaigns with codec="delta" replay
    codec="identity" bit for bit (same mode — cross-engine values only
    float-agree, see tests/test_engine.py)."""
    st_i, hist_i = _jit(case, "identity")
    st_d, hist_d = _jit(case, "delta")
    _assert_states_identical(st_i, st_d)
    assert hist_i == hist_d
    sc = _scenario(case, codec="delta")
    assert compile_counts(sc)["jit_round"] <= 1


def test_engine_scan_delta_bitwise():
    sc_i = _scenario("single", codec="identity")
    sc_d = _scenario("single", codec="delta")
    st_i, hist_i = run_campaign(sc_i, rounds=4, mode="scan")
    st_d, hist_d = run_campaign(sc_d, rounds=4, mode="scan")
    _assert_states_identical(st_i, st_d)
    assert hist_i == hist_d


# --------------------------------------------------------------------------
# stateful codec: EF threading, compile bounds, determinism
# --------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_engine_int8_compile_bounds_and_determinism(case):
    """delta_int8 grows the carry by the EF residual but still traces
    ONE round program per campaign, and the campaign is bitwise
    deterministic (same program, same schedule, same state out)."""
    sc = _scenario(case, codec="delta_int8")
    st1, hist1 = run_campaign(sc, rounds=4, mode="jit")
    st2, hist2 = run_campaign(sc, rounds=4, mode="jit")
    _assert_states_identical(st1, st2)
    assert hist1 == hist2
    assert compile_counts(sc)["jit_round"] == 1
    ef = st1.comms["ef"]
    assert ef.shape == (sc.cfg.vehicles_per_round,
                        flat_width(st1.global_tree))
    assert float(jnp.abs(ef).max()) > 0.0          # the residual is live


def test_engine_int8_scan_chunks_compose():
    """scan(2)+scan(2) == scan(4) bit for bit INCLUDING the comms state
    — the EF residual is part of the chunk carry, not a side channel."""
    sc = _scenario("single", codec="delta_int8")
    st4, hist4 = run_campaign(sc, rounds=4, mode="scan")
    st_a, hist_a = run_campaign(sc, rounds=2, mode="scan")
    st_b, hist_b = run_campaign(sc, st_a, rounds=2, mode="scan")
    _assert_states_identical(st4, st_b)
    assert hist_a + hist_b == hist4
    assert compile_counts(sc)["scan"] <= 2


def test_eager_int8_matches_engine_state_shapes_and_is_deterministic():
    """The eager path threads the same EF slots (slot = cohort
    position): two eager runs agree bitwise, and the residual evolves
    round over round."""
    sc = _scenario("multi", codec="delta_int8")
    st1, h1 = run(sc, rounds=2)
    st2, h2 = run(sc, rounds=2)
    _assert_states_identical(st1, st2)
    assert h1 == h2
    st0 = sc.init_state()
    assert st0.comms["ef"].shape == st1.comms["ef"].shape
    assert float(jnp.abs(st1.comms["ef"]).max()) > 0.0


def test_checkpoint_roundtrips_comms_state(tmp_path):
    """save/restore at round 2 then 2 more rounds == 4 straight rounds,
    bit for bit — the EF residual survives the npz structural spec."""
    sc = _scenario("single", codec="delta_int8")
    st4, hist4 = run_campaign(sc, rounds=4, mode="jit")
    st_ck, hist_ck = run_campaign(sc, rounds=4, mode="jit",
                                  checkpoint_every=2,
                                  checkpoint_dir=str(tmp_path))
    _assert_states_identical(st4, st_ck)
    assert hist_ck == hist4
    restored = restore_state(os.path.join(tmp_path, "round_000002"), sc)
    assert restored.round == 2
    np.testing.assert_array_equal(np.asarray(restored.comms["ef"]).shape,
                                  np.asarray(st4.comms["ef"]).shape)
    st_b, hist_b = run_campaign(sc, restored, rounds=2, mode="jit")
    _assert_states_identical(st4, st_b)
    assert hist_ck[:2] + hist_b == hist4


# --------------------------------------------------------------------------
# byte accounting
# --------------------------------------------------------------------------

def test_payload_bytes_delta_vs_int8():
    """The wire sizes behind BENCH_comms.json: a delta payload costs the
    same as the raw f32 upload; the int8 payload costs ~1.016
    bytes/parameter (codes + one f32 scale per 256-block)."""
    key = jax.random.PRNGKey(0)
    m, shapes = 4, ((32, 16), (512,))
    stacked = {"w": jax.random.normal(key, (m,) + shapes[0]),
               "b": jax.random.normal(jax.random.fold_in(key, 1),
                                      (m,) + shapes[1])}
    base = jax.tree.map(lambda x: x[0], stacked)
    raw = tree_nbytes(stacked)
    pay_d, _ = CODECS["delta"].encode(stacked, base)
    assert payload_nbytes(pay_d) == raw
    pay_q, _ = CODECS["delta_int8"].encode(stacked, base)
    P = flat_width(base)
    assert payload_nbytes(pay_q) == m * P + m * (P // 256) * 4
    assert payload_nbytes(pay_q) * 3.9 < raw


def test_roundtrip_cohort_identity_is_a_no_op():
    from repro.core.cohort import CohortBatch
    cfg = FLConfig(codec="identity")
    trees = {"w": jnp.arange(12.0).reshape(3, 4)}
    c = CohortBatch.from_stacked(trees, jnp.zeros((3,)))
    c2, comms = roundtrip_cohort(cfg, c, jax.tree.map(lambda x: x[0], trees),
                                 None)
    assert c2 is c and comms is None
