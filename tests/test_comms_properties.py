"""Property tests for the comms codecs (tier-1, hypothesis-driven).

Random trees, random valid counts, random bucket padding — always the
same three invariants:

  * lossless codecs reconstruct BIT FOR BIT (any float values, wrapped
    integer deltas never round), including through `roundtrip_cohort`
    on bucket-padded cohorts where the padding rows replicate the last
    valid row (the `pad_to` contract);
  * delta_int8's per-element error obeys the blockwise bound
    |decode - (delta + ef)| <= absmax_block / 254 (symmetric int8 with
    round-half-even), and the error-feedback residual IS that error —
    what the wire loses this round is exactly what folds in next round;
  * the cohort mask invariants survive the stage: n, size, losses, blur
    and every leaf shape/dtype are unchanged.

hypothesis is a dev-only dependency; the module skips when absent, like
tests/test_cohort_properties.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comms.codecs import CODECS, flat_width, roundtrip_cohort
from repro.core.cohort import CohortBatch
from repro.core.state import FLConfig

SETTINGS = settings(max_examples=40, deadline=None)

BQ = 256


def _tree(key, m, scale=1.0, dtypes=(jnp.float32, jnp.float32)):
    return {"w": (jax.random.normal(key, (m, 3, 5)) * scale).astype(
                dtypes[0]),
            "b": {"c": (jax.random.normal(jax.random.fold_in(key, 1),
                                          (m, 7)) * scale).astype(
                dtypes[1])}}


def _assert_bitwise(t1, t2):
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# lossless roundtrip
# --------------------------------------------------------------------------

@SETTINGS
@given(seed=st.integers(0, 2**16), m=st.integers(1, 6),
       scale=st.sampled_from([1e-8, 1.0, 1e8]),
       codec=st.sampled_from(["identity", "delta"]))
def test_lossless_roundtrip_bitwise(seed, m, scale, codec):
    key = jax.random.PRNGKey(seed)
    stacked = _tree(key, m, scale)
    base = _tree(jax.random.fold_in(key, 9), 1)
    base = jax.tree.map(lambda x: x[0], base)
    c = CODECS[codec]
    payload, ef = c.encode(stacked, base)
    assert ef is None
    _assert_bitwise(c.decode(payload, base), stacked)


def test_delta_roundtrip_survives_special_values():
    """Wrapping integer deltas reconstruct inf/nan/subnormal/-0.0 too —
    a plain float subtract cannot (inf - inf = nan)."""
    base = {"w": jnp.array([0.0, 1.0, -2.5, 3e38], jnp.float32)}
    weird = np.array([[np.inf, -np.inf, np.nan, -0.0],
                      [1e-40, -1e-40, np.float32(2.0) ** -149, 0.0]],
                     np.float32)
    stacked = {"w": jnp.asarray(weird)}
    c = CODECS["delta"]
    payload, _ = c.encode(stacked, base)
    out = c.decode(payload, base)
    np.testing.assert_array_equal(
        np.asarray(out["w"]).view(np.int32),
        weird.view(np.int32))                      # nan-safe bit compare


@SETTINGS
@given(seed=st.integers(0, 2**16), m=st.integers(1, 4))
def test_delta_roundtrip_stacked_base_and_int_leaves(seed, m):
    """Per-row bases (the handover download) and integer leaves (step
    counters and the like) roundtrip bitwise as well."""
    key = jax.random.PRNGKey(seed)
    stacked = {"w": jax.random.normal(key, (m, 4)),
               "n": jax.random.randint(jax.random.fold_in(key, 1),
                                       (m, 2), -1000, 1000)}
    bases = {"w": jax.random.normal(jax.random.fold_in(key, 2), (m, 4)),
             "n": jax.random.randint(jax.random.fold_in(key, 3),
                                     (m, 2), -1000, 1000)}
    c = CODECS["delta"]
    payload, _ = c.encode(stacked, bases, stacked_base=True)
    _assert_bitwise(c.decode(payload, bases, stacked_base=True), stacked)


@SETTINGS
@given(seed=st.integers(0, 2**16), n=st.integers(1, 5),
       pad=st.integers(0, 4))
def test_roundtrip_cohort_padded_bitwise(seed, n, pad):
    """Bucket-padded cohorts: `pad_to` replicates the last valid row, so
    the re-padded decoded cohort is bitwise the input cohort — masks,
    stats and all — for the lossless tier."""
    key = jax.random.PRNGKey(seed)
    trees = _tree(key, n)
    losses = jax.random.uniform(jax.random.fold_in(key, 2), (n,))
    blur = jax.random.uniform(jax.random.fold_in(key, 3), (n,),
                              minval=10.0, maxval=20.0)
    c = CohortBatch.from_stacked(trees, losses, blur=blur).pad_to(n + pad)
    base = jax.tree.map(lambda x: x[0], _tree(jax.random.fold_in(key, 9), 1))
    cfg = FLConfig(codec="delta")
    c2, comms = roundtrip_cohort(cfg, c, base, None)
    assert comms is None
    assert c2.n == c.n and c2.size == c.size
    _assert_bitwise(c2.trees, c.trees)
    _assert_bitwise({"l": c2.losses, "b": c2.blur}, {"l": c.losses,
                                                     "b": c.blur})


# --------------------------------------------------------------------------
# delta_int8 error bound + error feedback
# --------------------------------------------------------------------------

def _blockwise_absmax(y):
    m, P = y.shape
    return np.abs(y.reshape(m, P // BQ, BQ)).max(axis=-1)


@SETTINGS
@given(seed=st.integers(0, 2**16), m=st.integers(1, 4),
       scale=st.sampled_from([1e-4, 1.0, 1e4]))
def test_int8_error_within_blockwise_bound(seed, m, scale):
    key = jax.random.PRNGKey(seed)
    stacked = _tree(key, m, scale)
    base = jax.tree.map(lambda x: x[0],
                        _tree(jax.random.fold_in(key, 9), 1, scale))
    c = CODECS["delta_int8"]
    payload, new_ef = c.encode(stacked, base)
    out = c.decode(payload, base)
    # flatten the reconstruction error into the (m, Ppad) frame
    delta = jax.tree.map(lambda x, b: np.asarray(x - b[None]), stacked, base)
    err = jax.tree.map(lambda x, o: np.asarray(o) - np.asarray(x),
                       stacked, out)
    flat_d = np.concatenate(
        [np.asarray(l).reshape(m, -1) for l in jax.tree.leaves(delta)], 1)
    flat_e = np.concatenate(
        [np.asarray(l).reshape(m, -1) for l in jax.tree.leaves(err)], 1)
    P = flat_d.shape[1]
    padded = np.zeros((m, flat_width(base)), np.float32)
    padded[:, :P] = flat_d
    bound = _blockwise_absmax(padded) / 254.0
    bound = np.repeat(bound, BQ, axis=1)[:, :P]
    # float32 slack: scale/inv-scale each round once
    assert np.all(np.abs(flat_e) <= bound * (1 + 1e-5) + 1e-30)
    # the EF residual IS the (padded-frame) quantization error
    np.testing.assert_allclose(np.asarray(new_ef)[:, :P], -flat_e,
                               atol=max(1e-6, 1e-6 * scale))


def test_int8_error_feedback_telescopes():
    """Feeding the residual back makes the RUNNING SUM of decoded
    deltas track the running sum of true deltas to one quantization
    step — the error no longer accumulates round over round."""
    key = jax.random.PRNGKey(7)
    c = CODECS["delta_int8"]
    base = {"w": jnp.zeros((1, 300))}
    base0 = jax.tree.map(lambda x: x[0], base)
    ef = jnp.zeros((1, flat_width(base0)))
    acc_true = np.zeros((1, 300))
    acc_dec = np.zeros((1, 300))
    for r in range(6):
        stacked = {"w": jax.random.normal(jax.random.fold_in(key, r),
                                          (1, 300)) * 1e-3}
        payload, ef = c.encode(stacked, base0, ef)
        out = c.decode(payload, base0)
        acc_true += np.asarray(stacked["w"])
        acc_dec += np.asarray(out["w"])
        step = np.abs(np.asarray(stacked["w"]) + np.asarray(ef)[:, :300])
        bound = step.max() / 254.0 * 300          # generous single-step
        assert np.abs(acc_dec - acc_true).max() <= bound


@SETTINGS
@given(seed=st.integers(0, 2**16), n=st.integers(1, 4),
       pad=st.integers(0, 3))
def test_int8_roundtrip_cohort_mask_invariants(seed, n, pad):
    """The lossy stage still preserves every structural invariant: n,
    size, leaf shapes/dtypes, losses/blur untouched, EF slots outside
    [0, n) untouched."""
    key = jax.random.PRNGKey(seed)
    trees = _tree(key, n)
    losses = jax.random.uniform(jax.random.fold_in(key, 2), (n,))
    c = CohortBatch.from_stacked(trees, losses).pad_to(n + pad)
    base = jax.tree.map(lambda x: x[0], _tree(jax.random.fold_in(key, 9), 1))
    cfg = FLConfig(codec="delta_int8", vehicles_per_round=n + 2)
    from repro.comms.codecs import comms_init_state
    comms0 = comms_init_state(cfg, base)
    marker = comms0["ef"].at[n:].set(123.0)
    c2, comms = roundtrip_cohort(cfg, c, base, {"ef": marker})
    assert c2.n == c.n and c2.size == c.size
    for a, b in zip(jax.tree.leaves(c2.trees), jax.tree.leaves(c.trees)):
        assert a.shape == b.shape and a.dtype == b.dtype
    np.testing.assert_array_equal(np.asarray(c2.losses),
                                  np.asarray(c.losses))
    np.testing.assert_array_equal(np.asarray(comms["ef"][n:]),
                                  np.asarray(marker[n:]))
