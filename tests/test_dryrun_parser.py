"""HLO collective-byte parser + config-system utility tests.

(Importing repro.launch.dryrun appends to XLA_FLAGS; jax is already
initialized in the test process, so device count is unaffected.)
"""
import numpy as np
import pytest

from repro.configs.base import INPUT_SHAPES, get_config, list_configs, pad_vocab


FIXTURE_HLO = """
HloModule jit_step
  %p0 = bf16[128,256]{1,0} parameter(0)
  %ag = bf16[2048,256]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), to_apply=%add
  %rs = bf16[64,256]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = bf16[16,8,256]{2,1,0} all-to-all(%z), dimensions={0}
  %cp = f32[32,32]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %ard = f32[1024]{0} all-reduce-done(%ar)
  %other = f32[10]{0} add(%a, %b)
"""


def test_collective_bytes_parses_all_kinds():
    from repro.launch.dryrun import collective_bytes
    out = collective_bytes(FIXTURE_HLO)
    assert out["all-gather"] == 2048 * 256 * 2
    assert out["all-reduce"] == 1024 * 4
    assert out["reduce-scatter"] == 64 * 256 * 2
    assert out["all-to-all"] == 16 * 8 * 256 * 2
    assert out["collective-permute"] == 32 * 32 * 4
    assert out["count_all-gather"] == 1
    # "-done" ops must not be double counted
    assert out["count_all-reduce"] == 1


def test_collective_bytes_empty_on_plain_hlo():
    from repro.launch.dryrun import collective_bytes
    assert collective_bytes("%x = f32[8]{0} add(%a, %b)") == {}


def test_pad_vocab_multiples():
    assert pad_vocab(32000) == 32768
    assert pad_vocab(256206) % 2048 == 0
    assert pad_vocab(2048) == 2048


def test_registry_has_all_assigned_archs():
    expected = {"tinyllama-1.1b", "seamless-m4t-large-v2", "rwkv6-1.6b",
                "hymba-1.5b", "gemma2-27b", "kimi-k2-1t-a32b",
                "llama-3.2-vision-90b", "olmoe-1b-7b", "qwen2-0.5b",
                "deepseek-67b", "resnet18-cifar"}
    assert expected == set(list_configs())


def test_smoke_suffix_resolves():
    r = get_config("gemma2-27b-smoke")
    assert r.n_layers == 2 and r.d_model <= 256


def test_input_shapes_match_assignment():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == (524288, 1)
    assert s["decode_32k"].kind == "decode" and s["long_500k"].kind == "decode"


def test_n_params_sane_across_zoo():
    """Named sizes should be within ~35% of the advertised parameter
    counts (vocab padding + per-arch detail differences allowed)."""
    expect = {"tinyllama-1.1b": 1.1e9, "qwen2-0.5b": 0.5e9,
              "gemma2-27b": 27e9, "deepseek-67b": 67e9,
              "rwkv6-1.6b": 1.6e9, "hymba-1.5b": 1.5e9,
              "olmoe-1b-7b": 7e9}
    for name, n in expect.items():
        got = get_config(name).n_params()
        assert 0.6 * n < got < 1.6 * n, (name, got, n)
