"""Dual-temperature loss (Eq. 6-8): unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as stst

from repro.core.dt_loss import (_dt_from_logits, dt_loss, dt_loss_matrix,
                                info_nce_loss)


def _unit(key, b, d):
    x = jax.random.normal(key, (b, d))
    return x / jnp.linalg.norm(x, axis=-1, keepdims=True)


def test_equal_temperatures_reduce_to_infonce():
    """With tau_alpha == tau_beta the sg-weight is exactly 1, so the DT loss
    equals plain InfoNCE over the same logits."""
    key = jax.random.PRNGKey(0)
    q = _unit(key, 16, 32)
    k = _unit(jax.random.fold_in(key, 1), 16, 32)
    tau = 0.2
    dt = dt_loss_matrix(q, k, tau, tau)
    sim = q @ k.T / tau
    ce = -jnp.diagonal(jax.nn.log_softmax(sim, axis=-1)).mean()
    np.testing.assert_allclose(float(dt), float(ce), rtol=1e-5)


def test_weight_is_stop_gradient():
    """Gradients must flow only through the log-softmax term: gradient of
    dt at (tau_a, tau_b) with the weight detached equals gradient of
    weight_const * log p_a."""
    key = jax.random.PRNGKey(1)
    q = _unit(key, 8, 16)
    k = _unit(jax.random.fold_in(key, 2), 8, 16)

    g1 = jax.grad(lambda q: dt_loss_matrix(q, k, 0.1, 1.0))(q)

    def manual(qv):
        sim = qv @ k.T
        pos = jnp.diagonal(sim)
        log_pa = pos / 0.1 - jax.nn.logsumexp(sim / 0.1, axis=-1)
        w_a = 1 - jnp.exp(log_pa)
        w_b = 1 - jnp.exp(pos / 1.0 - jax.nn.logsumexp(sim / 1.0, axis=-1))
        w = jax.lax.stop_gradient(w_b / jnp.maximum(w_a, 1e-8))
        return (-w * log_pa).mean()

    g2 = jax.grad(manual)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


def test_perfect_alignment_gives_small_loss():
    """If q == k (positives trivially best), loss should be much smaller
    than for random pairs."""
    key = jax.random.PRNGKey(2)
    q = _unit(key, 32, 64)
    aligned = dt_loss_matrix(q, q, 0.1, 1.0)
    k = _unit(jax.random.fold_in(key, 3), 32, 64)
    random_ = dt_loss_matrix(q, k, 0.1, 1.0)
    assert float(aligned) < float(random_)


@settings(max_examples=25, deadline=None)
@given(b=stst.integers(2, 24), d=stst.integers(4, 64),
       seed=stst.integers(0, 2**31 - 1))
def test_loss_finite_and_nonnegative_weighting(b, d, seed):
    key = jax.random.PRNGKey(seed)
    q = _unit(key, b, d)
    k = _unit(jax.random.fold_in(key, 1), b, d)
    loss = dt_loss_matrix(q, k, 0.1, 1.0)
    assert np.isfinite(float(loss))
    # per-anchor weights w_b/w_a are positive => each -w*logp >= 0 whenever
    # p_pos <= 1 (log p <= 0), so the mean is nonnegative
    assert float(loss) >= 0.0


def test_explicit_negatives_form_matches_matrix_form():
    """dt_loss with k_neg = all k's (incl. the positive column duplicated)
    differs from matrix form; but with k_neg = k and pos prepended the
    logits sets coincide up to the duplicate positive — check the
    construction agrees on a hand-built case."""
    key = jax.random.PRNGKey(4)
    q = _unit(key, 6, 8)
    k = _unit(jax.random.fold_in(key, 5), 6, 8)
    # matrix form == explicit form using per-anchor negatives k_j (j != i)
    # build explicitly per anchor
    losses = []
    for i in range(6):
        negs = jnp.delete(k, i, axis=0)
        pos = jnp.sum(q[i] * k[i])[None, None]
        neg = (q[i:i + 1] @ negs.T)
        logits = jnp.concatenate([pos, neg], axis=-1)
        li = _dt_from_logits(logits, jnp.zeros((1,), jnp.int32), 0.1, 1.0)
        losses.append(float(li[0]))
    manual = np.mean(losses)
    mat = float(dt_loss_matrix(q, k, 0.1, 1.0))
    np.testing.assert_allclose(mat, manual, rtol=1e-5)


def test_info_nce_decreases_with_better_positives():
    key = jax.random.PRNGKey(6)
    q = _unit(key, 16, 32)
    queue = _unit(jax.random.fold_in(key, 7), 64, 32)
    good = info_nce_loss(q, q, queue)
    bad = info_nce_loss(q, _unit(jax.random.fold_in(key, 8), 16, 32), queue)
    assert float(good) < float(bad)
