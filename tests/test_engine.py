"""Campaign engine (core/engine.py) acceptance tests.

The compiled campaign's contract has three layers, each tested here:

  1. schedule — every pre-drawn quantity (cohort ids, batch indices,
     velocities, lr, key chain, host-RNG successor, handover positions/
     weights/sync decisions, every record field except the loss) is
     BITWISE identical to the eager `run` loop;
  2. reuse boundaries — the engine's batch construction and client step
     are the legacy functions, verified bitwise against the legacy
     cohort path on concrete arrays;
  3. within-mode determinism — for a fixed mode, any chunking and any
     save/restore split replays the campaign bit for bit, losses and
     model trees included (scan(a)+scan(b) == scan(a+b); the jit mode
     replays one identical program).

Cross-engine/cross-mode MODEL values agree only to float tolerance
(XLA fuses the round body differently from the op-by-op eager path —
see the engine module docstring), so no test compares losses or trees
ACROSS engines; the schedule layer plus the reuse-boundary layer pin
semantic equivalence instead.

Uses a micro payload (4x4 images, cohorts of 3) so each engine program
compiles in seconds; `ENGINE_TINY` is deliberately NOT the test_state
tiny-world (32x32 compiles are ~2 min per program on CI CPUs).
"""
import functools
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.store import restore_state, save_state
from repro.core import engine
from repro.core.clients import CLIENT_UPDATES, raw_local_step
from repro.core.engine import (check_campaign_supported, compile_counts,
                               resolve_mode, run_campaign)
from repro.core.scenario import Scenario, run
from repro.core.state import FLState, pack_host_rng, unpack_host_rng
from repro.core.topology import MultiRSU

_RS = np.random.RandomState(0)
DATA = [_RS.rand(6, 4, 4, 3).astype(np.float32) for _ in range(8)]

ENGINE_TINY = dict(data=DATA, n_vehicles=8, vehicles_per_round=3,
                   batch_size=2, rounds=6, local_iters=1, lr=0.4, seed=11)

CASES = {
    "single": dict(topology="single"),
    "multi": dict(topology="multi", topology_kwargs={"n_rsus": 2}),
    "handover": dict(topology="handover",
                     topology_kwargs={"n_rsus": 2, "rsu_range": 200.0,
                                      "round_duration": 50.0,
                                      "sync_every": 2}),
}


def _scenario(case: str, **over) -> Scenario:
    kw = {**ENGINE_TINY, **CASES[case]}
    if "topology_kwargs" in over:
        kw["topology_kwargs"] = {**kw.get("topology_kwargs", {}),
                                 **over.pop("topology_kwargs")}
    kw.update(over)
    return Scenario(**kw)


# memoized reference runs — compiled programs are shared through the
# engine's callable cache, these just avoid re-executing rounds per test
@functools.lru_cache(maxsize=None)
def _eager6(case):
    return run(_scenario(case), rounds=6)


@functools.lru_cache(maxsize=None)
def _jit6(case):
    return run_campaign(_scenario(case), rounds=6, mode="jit")


def _assert_states_identical(s1: FLState, s2: FLState):
    l1, l2 = jax.tree.leaves(s1.to_tree()), jax.tree.leaves(s2.to_tree())
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s1.round == s2.round


def _sans_loss(rec):
    return {k: v for k, v in rec.items() if k != "loss"}


# --------------------------------------------------------------------------
# layer 1: schedule bitwise vs eager
# --------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_schedule_and_records_match_eager(case):
    """Every record field except the loss, the RNG successor states and
    (for handover) the motion/accumulator state match the eager loop
    bit for bit."""
    st_e, hist_e = _eager6(case)
    st_c, hist_c = _jit6(case)
    assert len(hist_c) == len(hist_e) == 6
    for a, b in zip(hist_e, hist_c):
        assert _sans_loss(a) == _sans_loss(b)
        assert isinstance(b["loss"], float) and np.isfinite(b["loss"])
    np.testing.assert_array_equal(np.asarray(st_e.key), np.asarray(st_c.key))
    for k in st_e.host_rng:
        np.testing.assert_array_equal(np.asarray(st_e.host_rng[k]),
                                      np.asarray(st_c.host_rng[k]))
    assert st_c.round == st_e.round == 6
    if case == "handover":
        for k in ("positions", "blur_sum", "upload_count"):
            np.testing.assert_array_equal(np.asarray(st_e.topo[k]),
                                          np.asarray(st_c.topo[k]))


@pytest.mark.parametrize("case", ["single", "handover"])
@pytest.mark.parametrize("seed", [0, 3, 7])
def test_plan_is_chunking_invariant(case, seed):
    """Property: planning a campaign in one chunk or in pieces yields the
    SAME schedule arrays, records and RNG successors — the invariant
    that makes checkpoint_every (which re-plans per chunk) bit-exact."""
    sc = _scenario(case, seed=seed)

    def plan(chunks):
        state = sc.init_state()
        xs_all, recs_all = [], []
        for k in chunks:
            xs, recs, key, rng, topo_host = engine._plan_chunk(state, sc, k)
            xs_all += xs
            recs_all += recs
            topo = state.topo
            if topo_host:
                topo = {**topo, **topo_host}
            state = state.replace(key=key, host_rng=pack_host_rng(rng),
                                  round=state.round + k, topo=topo)
        return xs_all, recs_all, state

    xs1, recs1, end1 = plan([6])
    xs2, recs2, end2 = plan([2, 2, 2])
    assert recs1 == recs2
    for row1, row2 in zip(xs1, xs2):
        for a, b in zip(row1, row2):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(end1.key), np.asarray(end2.key))
    for k in end1.host_rng:
        np.testing.assert_array_equal(np.asarray(end1.host_rng[k]),
                                      np.asarray(end2.host_rng[k]))
    if case == "handover":
        np.testing.assert_array_equal(end1.topo["positions"],
                                      end2.topo["positions"])


# --------------------------------------------------------------------------
# layer 2: reuse boundaries bitwise vs the legacy cohort path
# --------------------------------------------------------------------------

def test_batches_and_client_step_match_legacy():
    """The engine's batch construction and client step ARE the legacy
    ones: on concrete arrays (outside the fused body) both produce
    bitwise-identical batches, losses and client trees."""
    from repro.core.topology import _client_images

    sc = _scenario("single")
    state = sc.init_state()
    xs_list, _, _, _, _ = engine._plan_chunk(state, sc, 1)
    ids, idx, cks, velocities, blur, lr = xs_list[0]

    # batch construction: stacked gather + vmapped blur == per-client
    # eager slicing + blur
    dstack = engine._data_stack(sc)
    batches = engine._client_batches(dstack, ids, idx, velocities, sc)
    legacy = np.stack([
        np.asarray(_client_images(sc, int(c), np.asarray(idx)[i],
                                  velocities[i]))
        for i, c in enumerate(np.asarray(ids))])
    np.testing.assert_array_equal(np.asarray(batches), legacy)

    # client step: jit(vmap(raw_local_step)) == the legacy cohort path
    cohort, _ = CLIENT_UPDATES["dtssl"].run_cohort(
        sc.cfg, state.global_tree, None, batches, list(cks), lr,
        parallel=True)
    step = jax.jit(jax.vmap(raw_local_step(sc.cfg),
                            in_axes=(None, 0, 0, None)))
    trees, losses = step(state.global_tree, batches, cks, lr)
    np.testing.assert_array_equal(np.asarray(losses),
                                  np.asarray(cohort.losses))
    for a, b in zip(jax.tree.leaves(trees), jax.tree.leaves(cohort.trees)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# layer 3: within-mode bit-exactness (chunking + save/restore)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("case", sorted(CASES))
def test_jit_resume_bit_exact(case, tmp_path):
    """mode="jit": 6 rounds straight == 6 rounds with checkpoint_every=3
    == restore the round-3 checkpoint + 3 more rounds, bit for bit, and
    the whole campaign compiles exactly ONE round program."""
    sc = _scenario(case)
    st6, hist6 = _jit6(case)

    st_ck, hist_ck = run_campaign(sc, rounds=6, mode="jit",
                                  checkpoint_every=3,
                                  checkpoint_dir=str(tmp_path))
    _assert_states_identical(st6, st_ck)
    assert hist_ck == hist6

    restored = restore_state(os.path.join(tmp_path, "round_000003"), sc)
    assert restored.round == 3
    st_b, hist_b = run_campaign(sc, restored, rounds=3, mode="jit")
    _assert_states_identical(st6, st_b)
    assert hist_ck[:3] + hist_b == hist6
    assert compile_counts(sc)["jit_round"] == 1


@pytest.mark.parametrize("case", ["single", "handover"])
def test_scan_chunks_compose(case):
    """mode="scan": scan(3)+scan(3) == scan(6) bit for bit (losses, model
    trees, full FLState), with <= 2 compiled scan programs (one per
    distinct chunk length)."""
    sc = _scenario(case)
    st6, hist6 = run_campaign(sc, rounds=6, mode="scan")
    st_a, hist_a = run_campaign(sc, rounds=3, mode="scan")
    st_b, hist_b = run_campaign(sc, st_a, rounds=3, mode="scan")
    _assert_states_identical(st6, st_b)
    assert hist_a + hist_b == hist6
    # same schedule as the jit mode (the plan is mode-independent)
    assert [_sans_loss(r) for r in hist6] == \
        [_sans_loss(r) for r in _jit6(case)[1]]
    assert compile_counts(sc)["scan"] <= 2


def test_log_every_formats_from_chunk_history(capsys):
    """log_every on the compiled path prints the SAME line format as the
    eager loop, assembled from the once-per-chunk fetched history — and
    logging does not perturb the campaign."""
    sc = _scenario("single")
    st_plain, hist = run_campaign(sc, rounds=4, mode="jit")
    capsys.readouterr()
    st_log, hist_log = run_campaign(sc, rounds=4, mode="jit", log_every=2)
    lines = capsys.readouterr().out.splitlines()
    want = [f"[round {r['round']:4d}] loss={r['loss']:.4f} "
            f"lr={r['lr']:.4f}" for r in hist if r["round"] % 2 == 0]
    assert lines == want
    assert hist_log == hist
    _assert_states_identical(st_plain, st_log)

    # the eager loop prints byte-identical lines for ITS history rows
    capsys.readouterr()
    _, hist_e = run(sc, rounds=4, log_every=2)
    lines_e = capsys.readouterr().out.splitlines()
    want_e = [f"[round {r['round']:4d}] loss={r['loss']:.4f} "
              f"lr={r['lr']:.4f}" for r in hist_e if r["round"] % 2 == 0]
    assert lines_e == want_e


# --------------------------------------------------------------------------
# guard rails
# --------------------------------------------------------------------------

def test_round_body_no_implicit_transfers():
    """The steady-state campaign dispatch is transfer-clean: with
    ``transfer_guard=True`` the fused round body runs under
    ``jax.transfer_guard("disallow")`` (via ``analysis.guards``), so any
    implicit host<->device movement inside the round loop raises. The
    only sanctioned transfer is the explicit once-per-chunk
    ``jax.device_get`` history fetch. The program is warmed first —
    lowering's constant uploads are outside the guarded window by
    design — and the guard must not perturb the campaign: the guarded
    run replays the unguarded one bit for bit."""
    st_ref, hist_ref = _jit6("single")
    st, hist = run_campaign(_scenario("single"), rounds=6, mode="jit",
                            transfer_guard=True)
    _assert_states_identical(st, st_ref)
    assert hist == hist_ref

    # the guard itself is live, not a no-op: an implicit transfer inside
    # the same context manager the engine uses does raise
    from repro.analysis.guards import no_implicit_transfers
    with pytest.raises(Exception, match="[Dd]isallow"):
        with no_implicit_transfers():
            jax.jit(lambda v: v + 1)(np.ones(3))  # numpy leaks into jit


def test_unsupported_configs_fail_fast():
    with pytest.raises(ValueError, match="sequential"):
        check_campaign_supported(
            Scenario(**{**ENGINE_TINY, "topology": "single",
                        "client": "fedco", "aggregator": "fedavg",
                        "queue_len": 16}))
    sc_mesh = _scenario("multi")
    # constructed directly: Scenario.validate would already reject the
    # collective on a 1-device box, before the engine check runs
    sc_mesh.topology = MultiRSU(n_rsus=2, mesh_aggregate=True)
    with pytest.raises(ValueError, match="mesh_aggregate"):
        check_campaign_supported(sc_mesh)

    class CustomTopo(MultiRSU):
        pass

    sc = _scenario("single")
    sc.topology = CustomTopo(n_rsus=2)
    with pytest.raises(ValueError, match="built-in"):
        check_campaign_supported(sc)

    with pytest.raises(ValueError, match="mode"):
        resolve_mode("eager")
    with pytest.raises(ValueError, match="checkpoint_dir"):
        run_campaign(_scenario("single"), rounds=1, checkpoint_every=1)
    with pytest.raises(ValueError, match=">= 1"):
        run_campaign(_scenario("single"), rounds=1, checkpoint_every=0,
                     checkpoint_dir="/tmp/x")


def test_checkpoint_refuses_other_topology_params(tmp_path):
    """The store fingerprint includes topology.signature() params: a
    handover checkpoint taken under sync_every=2 must not resume under
    sync_every=3 (same shapes — only the schedule differs)."""
    sc2 = _scenario("handover")
    state = sc2.init_state()
    path = save_state(os.path.join(tmp_path, "ck"), state, sc2)
    sc3 = _scenario("handover", topology_kwargs={"sync_every": 3})
    with pytest.raises(ValueError, match="topology_params"):
        restore_state(path, sc3)
    back = restore_state(path, sc2)
    _assert_states_identical(state, back)


def test_auto_mode_resolution():
    want = "jit" if jax.default_backend() == "cpu" else "scan"
    assert resolve_mode("auto") == want
    assert resolve_mode("jit") == "jit"
    assert resolve_mode("scan") == "scan"
