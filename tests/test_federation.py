"""FL loop integration tests (reduced scale, CPU-friendly)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.federation import (FLConfig, FederatedTrainer, gradient_std,
                                   make_local_train_step)
from repro.data.synthetic import (category_histogram, make_dataset,
                                  partition_dirichlet, partition_iid)
from repro.models.resnet import init_resnet


@pytest.fixture(scope="module")
def tiny_world():
    x, y = make_dataset(n_per_class=40, seed=0)
    parts = partition_iid(y, 6)
    tree = init_resnet(get_config("resnet18-cifar"), jax.random.PRNGKey(0))
    return x, y, parts, tree


def test_parallel_and_sequential_rounds_agree(tiny_world):
    x, y, parts, tree = tiny_world
    cfg = FLConfig(n_vehicles=6, vehicles_per_round=2, batch_size=16,
                   rounds=1, local_iters=1, seed=42)
    data = [x[p] for p in parts]
    tr1 = FederatedTrainer(cfg, tree, data)
    tr2 = FederatedTrainer(cfg, tree, data)
    r1 = tr1.round(0, parallel=True)
    r2 = tr2.round(0, parallel=False)
    np.testing.assert_allclose(r1["loss"], r2["loss"], rtol=1e-4)
    for l1, l2 in zip(jax.tree.leaves(tr1.global_tree),
                      jax.tree.leaves(tr2.global_tree)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)


@pytest.mark.slow
def test_loss_decreases_over_rounds(tiny_world):
    x, y, parts, tree = tiny_world
    cfg = FLConfig(n_vehicles=6, vehicles_per_round=3, batch_size=32,
                   rounds=6, local_iters=1, lr=0.3, seed=1)
    tr = FederatedTrainer(cfg, tree, [x[p] for p in parts])
    hist = tr.run(log_every=0)
    first, last = hist[0]["loss"], np.mean([h["loss"] for h in hist[-2:]])
    assert np.isfinite(last)
    assert last < first * 1.5  # descent-ish (short runs are noisy)


def test_all_aggregators_run_one_round(tiny_world):
    x, y, parts, tree = tiny_world
    data = [x[p] for p in parts]
    for aggname in ("flsimco", "fedavg", "discard", "fedco"):
        cfg = FLConfig(n_vehicles=6, vehicles_per_round=2, batch_size=8,
                       rounds=1, aggregator=aggname, queue_len=128, seed=2)
        tr = FederatedTrainer(cfg, tree, data)
        rec = tr.round(0, parallel=False)
        assert np.isfinite(rec["loss"])


def test_dirichlet_partition_respects_floor_and_skew():
    _, y = make_dataset(n_per_class=100, seed=1)
    parts = partition_dirichlet(y, 10, alpha=0.1, min_per_client=50, seed=0)
    sizes = [len(p) for p in parts]
    assert min(sizes) >= 50
    assert sum(sizes) == len(y)
    hist = category_histogram(y, parts)
    # Non-IID: at least one client should be dominated by few classes
    frac_top2 = np.sort(hist, axis=1)[:, -2:].sum(1) / np.maximum(
        hist.sum(1), 1)
    assert frac_top2.max() > 0.5


def test_iid_partition_is_balanced():
    _, y = make_dataset(n_per_class=100, seed=2)
    parts = partition_iid(y, 10)
    hist = category_histogram(y, parts)
    assert hist.min() > 0  # every class on every client


def test_gradient_std_metric():
    smooth = [1.0, 0.9, 0.8, 0.7]
    noisy = [1.0, 0.5, 0.9, 0.2]
    assert gradient_std(noisy) > gradient_std(smooth)


def test_fedco_queue_grows_with_uploads(tiny_world):
    x, y, parts, tree = tiny_world
    cfg = FLConfig(n_vehicles=6, vehicles_per_round=2, batch_size=8,
                   rounds=1, aggregator="fedco", queue_len=64, seed=3)
    tr = FederatedTrainer(cfg, tree, [x[p] for p in parts])
    q0 = np.asarray(tr.global_queue).copy()
    tr.round(0)
    q1 = np.asarray(tr.global_queue)
    assert q1.shape == q0.shape          # fixed length
    assert not np.allclose(q0, q1)       # but contents updated
