"""Hierarchical (multi-RSU) aggregation — beyond-paper extension tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.aggregation import aggregate_flsimco
from repro.core.hierarchical import (aggregate_hierarchical,
                                     two_stage_weighted_psum)


def _trees(key, n):
    return [{"w": jax.random.normal(jax.random.fold_in(key, i), (3, 4))}
            for i in range(n)]


def test_single_rsu_reduces_to_flat_eq11():
    key = jax.random.PRNGKey(0)
    trees = _trees(key, 5)
    blur = jnp.array([1.0, 2.0, 3.0, 4.0, 5.0])
    h = aggregate_hierarchical([trees], [blur])
    f = aggregate_flsimco(trees, blur)
    np.testing.assert_allclose(np.asarray(h["w"]), np.asarray(f["w"]),
                               atol=1e-5)


def test_hierarchical_equals_flat_under_symmetric_blur():
    """Equal per-RSU mean blur + count scaling + equal counts => the
    two-level weights coincide with a flat aggregation of RSU models."""
    key = jax.random.PRNGKey(1)
    g1, g2 = _trees(key, 3), _trees(jax.random.fold_in(key, 9), 3)
    b = jnp.array([2.0, 3.0, 4.0])
    h = aggregate_hierarchical([g1, g2], [b, b])
    # flat equivalent: aggregate each RSU, then plain average (equal Lbar)
    r1 = aggregate_flsimco(g1, b)
    r2 = aggregate_flsimco(g2, b)
    expect = jax.tree.map(lambda a, c: (a + c) / 2, r1, r2)
    np.testing.assert_allclose(np.asarray(h["w"]), np.asarray(expect["w"]),
                               atol=1e-5)


def test_blurrier_rsu_gets_less_weight():
    key = jax.random.PRNGKey(2)
    sharp = _trees(key, 2)
    blurry = _trees(jax.random.fold_in(key, 7), 2)
    h = aggregate_hierarchical([sharp, blurry],
                               [jnp.array([1.0, 1.0]), jnp.array([9.0, 9.0])])
    r_sharp = aggregate_flsimco(sharp, jnp.array([1.0, 1.0]))
    # result should sit closer to the sharp RSU's model than a plain mean
    r_blurry = aggregate_flsimco(blurry, jnp.array([9.0, 9.0]))
    d_sharp = float(jnp.abs(h["w"] - r_sharp["w"]).mean())
    d_blurry = float(jnp.abs(h["w"] - r_blurry["w"]).mean())
    assert d_sharp < d_blurry


def test_two_stage_psum_matches_host_hierarchical():
    """shard_map two-stage collective == host-level hierarchical result.
    Uses a (pod=1, data=N) mesh on whatever devices exist; with one pod
    level 2 is an identity, matching a single-RSU host aggregation."""
    n = jax.device_count()
    mesh = jax.make_mesh((1, n), ("pod", "data"))
    key = jax.random.PRNGKey(3)
    trees = _trees(key, n)
    blur = jnp.arange(1.0, n + 1.0)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *trees)

    def per_cohort(tree, L):
        return two_stage_weighted_psum(
            jax.tree.map(lambda x: x[0], tree), L[0])

    from repro.compat import shard_map
    fn = shard_map(per_cohort, mesh=mesh,
                   in_specs=(P(("pod", "data")), P(("pod", "data"))),
                   out_specs=P(), check=False)
    out = fn(stacked, blur)
    expect = aggregate_hierarchical([trees], [blur])
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(expect["w"]),
                               atol=1e-5)
