"""Hierarchical (multi-RSU) aggregation — beyond-paper extension tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.aggregation import aggregate_flsimco
from repro.core.hierarchical import (aggregate_hierarchical,
                                     two_stage_weighted_psum)


def _trees(key, n):
    return [{"w": jax.random.normal(jax.random.fold_in(key, i), (3, 4))}
            for i in range(n)]


def test_single_rsu_reduces_to_flat_eq11():
    key = jax.random.PRNGKey(0)
    trees = _trees(key, 5)
    blur = jnp.array([1.0, 2.0, 3.0, 4.0, 5.0])
    h = aggregate_hierarchical([trees], [blur])
    f = aggregate_flsimco(trees, blur)
    np.testing.assert_allclose(np.asarray(h["w"]), np.asarray(f["w"]),
                               atol=1e-5)


def test_hierarchical_equals_flat_under_symmetric_blur():
    """Equal per-RSU mean blur + count scaling + equal counts => the
    two-level weights coincide with a flat aggregation of RSU models."""
    key = jax.random.PRNGKey(1)
    g1, g2 = _trees(key, 3), _trees(jax.random.fold_in(key, 9), 3)
    b = jnp.array([2.0, 3.0, 4.0])
    h = aggregate_hierarchical([g1, g2], [b, b])
    # flat equivalent: aggregate each RSU, then plain average (equal Lbar)
    r1 = aggregate_flsimco(g1, b)
    r2 = aggregate_flsimco(g2, b)
    expect = jax.tree.map(lambda a, c: (a + c) / 2, r1, r2)
    np.testing.assert_allclose(np.asarray(h["w"]), np.asarray(expect["w"]),
                               atol=1e-5)


def test_blurrier_rsu_gets_less_weight():
    key = jax.random.PRNGKey(2)
    sharp = _trees(key, 2)
    blurry = _trees(jax.random.fold_in(key, 7), 2)
    h = aggregate_hierarchical([sharp, blurry],
                               [jnp.array([1.0, 1.0]), jnp.array([9.0, 9.0])])
    r_sharp = aggregate_flsimco(sharp, jnp.array([1.0, 1.0]))
    # result should sit closer to the sharp RSU's model than a plain mean
    r_blurry = aggregate_flsimco(blurry, jnp.array([9.0, 9.0]))
    d_sharp = float(jnp.abs(h["w"] - r_sharp["w"]).mean())
    d_blurry = float(jnp.abs(h["w"] - r_blurry["w"]).mean())
    assert d_sharp < d_blurry


def test_two_stage_psum_f64_accum_tightens_error():
    """accum_dtype=jnp.float64 (under enable_x64) accumulates BOTH
    weighted-psum levels in f64 and casts back to f32 once, after level
    2 — on a cancellation-heavy cohort the result lands within one f32
    rounding of the exact (f64 host) weighted sum, where the default
    f32 accumulation does not. The default (accum_dtype=None) keeps the
    original op sequence — pinned bit-compatible with the mesh tests in
    tests/multidevice/."""
    from repro.core.hierarchical import sharded_hierarchical
    mesh = jax.make_mesh((1, 1), ("pod", "data"))
    rng = np.random.RandomState(0)
    b = 8
    # alternating +-3e4 rows bury the O(1) signal in f32 partial sums
    big = np.tile([3e4, -3e4], b // 2)[:, None, None]
    x = (rng.randn(b, 4, 5) + big).astype(np.float32)
    trees = {"w": jnp.asarray(x)}
    blur = jnp.asarray(rng.uniform(10.0, 20.0, b).astype(np.float32))

    # the exact reference: the function's own f32 weights, accumulated
    # in numpy float64, rounded to f32 at the end
    L = np.asarray(blur, np.float32)
    w1 = (L.sum() - L) / L.sum()
    w1 = (w1 / w1.sum()).astype(np.float32)
    expect = np.tensordot(w1.astype(np.float64),
                          x.astype(np.float64), axes=1).astype(np.float32)

    got32 = sharded_hierarchical(trees, blur, mesh, 1, reduction="psum")
    with jax.experimental.enable_x64():
        got64 = sharded_hierarchical(trees, blur, mesh, 1,
                                     reduction="psum",
                                     accum_dtype=jnp.float64)
    assert got64["w"].dtype == jnp.float32          # cast back after level 2
    err32 = np.abs(np.asarray(got32["w"], np.float64) - expect).max()
    err64 = np.abs(np.asarray(got64["w"], np.float64) - expect).max()
    np.testing.assert_allclose(np.asarray(got64["w"]), expect,
                               atol=2e-6, rtol=1e-6)   # tightened
    assert err64 <= err32
    # the f32 path only promises the documented ~1e-5-relative regime:
    # here (|terms| ~ 3e4) its absolute error is visibly larger
    assert err32 > 10 * max(err64, 1e-9)


def test_two_stage_psum_matches_host_hierarchical():
    """shard_map two-stage collective == host-level hierarchical result.
    Uses a (pod=1, data=N) mesh on whatever devices exist; with one pod
    level 2 is an identity, matching a single-RSU host aggregation."""
    n = jax.device_count()
    mesh = jax.make_mesh((1, n), ("pod", "data"))
    key = jax.random.PRNGKey(3)
    trees = _trees(key, n)
    blur = jnp.arange(1.0, n + 1.0)
    stacked = jax.tree.map(lambda *ls: jnp.stack(ls), *trees)

    def per_cohort(tree, L):
        return two_stage_weighted_psum(
            jax.tree.map(lambda x: x[0], tree), L[0])

    from repro.compat import shard_map
    fn = shard_map(per_cohort, mesh=mesh,
                   in_specs=(P(("pod", "data")), P(("pod", "data"))),
                   out_specs=P(), check=False)
    out = fn(stacked, blur)
    expect = aggregate_hierarchical([trees], [blur])
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(expect["w"]),
                               atol=1e-5)
