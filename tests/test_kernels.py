"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.dt_loss import dt_loss_fwd_pallas
from repro.kernels.rwkv6 import rwkv6_pallas
from repro.kernels.wagg import wagg_pallas


def _unit(key, shape, dtype=jnp.float32):
    x = jax.random.normal(key, shape, jnp.float32)
    x = x / jnp.linalg.norm(x, axis=-1, keepdims=True)
    return x.astype(dtype)


# --------------------------------------------------------------------------
# dt_loss
# --------------------------------------------------------------------------

@pytest.mark.parametrize("M", [128, 256, 384])
@pytest.mark.parametrize("D", [32, 128, 256])
def test_dt_loss_kernel_shape_sweep(M, D):
    key = jax.random.PRNGKey(M * 1000 + D)
    q = _unit(key, (M, D))
    k = _unit(jax.random.fold_in(key, 1), (M, D))
    l1, la1, lb1, p1 = dt_loss_fwd_pallas(q, k, 0.1, 1.0, n_valid=M)
    l2, la2, lb2, p2 = ref.dt_loss_fwd_ref(q, k, 0.1, 1.0)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(la1), np.asarray(la2), atol=2e-5)
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dt_loss_kernel_dtype_sweep(dtype):
    key = jax.random.PRNGKey(7)
    q = _unit(key, (128, 64), dtype)
    k = _unit(jax.random.fold_in(key, 1), (128, 64), dtype)
    l1 = ops.dt_loss(q, k, 0.1, 1.0)
    l2 = ref.dt_loss_ref(q.astype(jnp.float32), k.astype(jnp.float32), 0.1, 1.0)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(float(l1), float(l2), rtol=tol, atol=tol)


@pytest.mark.parametrize("M", [96, 130, 200])  # padding paths
def test_dt_loss_wrapper_handles_unaligned_batch(M):
    key = jax.random.PRNGKey(M)
    q = _unit(key, (M, 48))
    k = _unit(jax.random.fold_in(key, 1), (M, 48))
    l1 = float(ops.dt_loss(q, k, 0.1, 1.0))
    l2 = float(ref.dt_loss_ref(q, k, 0.1, 1.0))
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("taus", [(0.07, 1.0), (0.1, 0.5), (0.2, 0.2)])
def test_dt_loss_grad_matches_reference(taus):
    ta, tb = taus
    key = jax.random.PRNGKey(11)
    q = _unit(key, (64, 32))
    k = _unit(jax.random.fold_in(key, 1), (64, 32))
    from repro.core.dt_loss import dt_loss_matrix
    g1 = jax.grad(lambda q, k: ops.dt_loss(q, k, ta, tb), (0, 1))(q, k)
    g2 = jax.grad(lambda q, k: dt_loss_matrix(q, k, ta, tb), (0, 1))(q, k)
    np.testing.assert_allclose(np.asarray(g1[0]), np.asarray(g2[0]), atol=1e-5)
    np.testing.assert_allclose(np.asarray(g1[1]), np.asarray(g2[1]), atol=1e-5)


# --------------------------------------------------------------------------
# wagg
# --------------------------------------------------------------------------

@pytest.mark.parametrize("N", [2, 5, 16])
@pytest.mark.parametrize("P", [2048, 4096, 8192])
def test_wagg_kernel_shape_sweep(N, P):
    key = jax.random.PRNGKey(N * 100 + P)
    x = jax.random.normal(key, (N, P))
    w = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (N,)))
    np.testing.assert_allclose(np.asarray(wagg_pallas(x, w)),
                               np.asarray(ref.wagg_ref(x, w)), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wagg_dtype_sweep(dtype):
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (4, 1000)).astype(dtype)   # unaligned P
    w = jnp.array([0.1, 0.2, 0.3, 0.4])
    out = ops.wagg_flat(x, w)
    expect = ref.wagg_ref(x.astype(jnp.float32), w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


# --------------------------------------------------------------------------
# qdelta (int8 comms codec)
# --------------------------------------------------------------------------

def _q8_case(key, N, P, scale=1.0):
    flat = jax.random.normal(key, (N, P)) * scale
    ef = jax.random.normal(jax.random.fold_in(key, 1), (N, P)) * scale * 0.01
    return flat, ef


@pytest.mark.parametrize("N", [1, 3])
@pytest.mark.parametrize("P", [256, 1024, 4096])
def test_q8_encode_parity_interpret_vs_ref(N, P):
    """The Pallas kernel and the jnp reference are BITWISE identical on
    codes and scales (the wire payload — `absmax * (1/127)` is a single
    rounding both lowerings share). new_ef only float-agrees: XLA is
    free to FMA-fuse `y - codes*scales` differently per backend."""
    flat, ef = _q8_case(jax.random.PRNGKey(N * 1000 + P), N, P)
    c1, s1, e1 = ops.q8_encode_flat(flat, ef, backend="interpret")
    c2, s2, e2 = ops.q8_encode_flat(flat, ef, backend="ref")
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-6)


@pytest.mark.parametrize("P", [256, 2048])
def test_q8_decode_parity_interpret_vs_ref(P):
    """Dequantize is a plain broadcast-multiply — bitwise across
    backends, so the RECONSTRUCTED models (what aggregation consumes)
    never depend on where the codec ran."""
    flat, ef = _q8_case(jax.random.PRNGKey(P), 2, P)
    codes, scales, _ = ops.q8_encode_flat(flat, ef, backend="ref")
    o1 = ops.q8_decode_flat(codes, scales, backend="interpret")
    o2 = ops.q8_decode_flat(codes, scales, backend="ref")
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_q8_roundtrip_semantics():
    """Blockwise symmetric int8: codes bounded, zero blocks exact, the
    residual is exactly y - dequantized(y)."""
    key = jax.random.PRNGKey(5)
    flat = jnp.concatenate([jax.random.normal(key, (2, 256)),
                            jnp.zeros((2, 256))], axis=1)
    ef = jnp.zeros_like(flat)
    codes, scales, new_ef = ops.q8_encode_flat(flat, ef, backend="ref")
    assert codes.dtype == jnp.int8 and scales.shape == (2, 2)
    assert int(jnp.max(jnp.abs(codes))) <= 127
    # all-zero block: zero scale, zero codes, zero error (guarded 1/s)
    np.testing.assert_array_equal(np.asarray(codes[:, 256:]), 0)
    np.testing.assert_array_equal(np.asarray(scales[:, 1]), 0.0)
    out = ops.q8_decode_flat(codes, scales, backend="ref")
    np.testing.assert_allclose(np.asarray(out + new_ef), np.asarray(flat),
                               atol=1e-6)
    # per-element bound: |y - deq| <= absmax_block / 254
    bound = np.abs(np.asarray(flat)).reshape(2, 2, 256).max(-1) / 254.0
    err = np.abs(np.asarray(flat - out)).reshape(2, 2, 256).max(-1)
    assert np.all(err <= bound * (1 + 1e-6) + 1e-30)


def test_q8_error_feedback_is_folded_in():
    """encode(flat, ef) quantizes flat + ef, not flat alone."""
    key = jax.random.PRNGKey(9)
    flat = jax.random.normal(key, (1, 256))
    ef = jax.random.normal(jax.random.fold_in(key, 1), (1, 256)) * 0.1
    c1, s1, _ = ops.q8_encode_flat(flat, ef, backend="ref")
    c2, s2, _ = ops.q8_encode_flat(flat + ef, jnp.zeros_like(ef),
                                   backend="ref")
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


# --------------------------------------------------------------------------
# rwkv6
# --------------------------------------------------------------------------

@pytest.mark.parametrize("S", [16, 64, 128])
@pytest.mark.parametrize("D", [32, 64])
def test_rwkv6_kernel_shape_sweep(S, D):
    key = jax.random.PRNGKey(S + D)
    ks = jax.random.split(key, 5)
    BH = 3
    r, k, v = (jax.random.normal(ks[i], (BH, S, D)) * 0.5 for i in range(3))
    logw = -jnp.exp(jax.random.normal(ks[3], (BH, S, D)) * 0.3 - 1.0)
    logw = jnp.clip(logw, -4.0, -1e-4)
    u = jax.random.normal(ks[4], (D,)) * 0.3
    o1, s1 = rwkv6_pallas(r, k, v, logw, u)
    o2, s2 = ref.rwkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=2e-4)


def test_rwkv6_wrapper_pads_sequence():
    key = jax.random.PRNGKey(77)
    ks = jax.random.split(key, 5)
    BH, S, D = 2, 37, 32                       # S not chunk-aligned
    r, k, v = (jax.random.normal(ks[i], (BH, S, D)) * 0.5 for i in range(3))
    logw = jnp.clip(-jnp.exp(jax.random.normal(ks[3], (BH, S, D))), -4, -1e-4)
    u = jax.random.normal(ks[4], (D,)) * 0.3
    o1, _ = ops.rwkv6(r, k, v, logw, u)
    o2, _ = ref.rwkv6_ref(r, k, v, logw, u)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)


def test_rwkv6_kernel_agrees_with_model_layer():
    """The model's chunked jnp path and the Pallas kernel implement the
    same recurrence."""
    from repro.configs.base import get_config
    from repro.models import layers as L
    cfg = get_config("rwkv6-1.6b").reduced()
    key = jax.random.PRNGKey(5)
    p = L.init_rwkv_tmix(cfg, key)
    B, S, d = 2, 32, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.1
    # jnp chunked layer output
    o_layer, state_layer, _ = L.rwkv_tmix_chunked(cfg, p, x)
    # same projections -> kernel
    x_prev = jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    r, k, v, g, logw = L._rwkv_project(cfg, p, x, x_prev)
    D = cfg.rwkv_head_dim
    H = d // D
    def to_bh(t):
        return t.astype(jnp.float32).reshape(B, S, H, D).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    u = p["u"].astype(jnp.float32).reshape(H, D)
    u_bh = jnp.tile(u, (B, 1))
    o_k, _ = ref.rwkv6_ref(to_bh(r), to_bh(k), to_bh(v), to_bh(logw), u_bh)
    # Pallas kernel with the same per-head u must agree with the oracle
    o_pal, _ = ops.rwkv6(to_bh(r), to_bh(k), to_bh(v), to_bh(logw), u_bh)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_k), atol=2e-4)
    # compare layer vs sequential-ref (ground truth)
    o_ref = o_k.reshape(B, H, S, D).transpose(0, 2, 1, 3).reshape(B, S, d)
    o_ref = (o_ref.astype(x.dtype) * g) @ p["wo"]
    np.testing.assert_allclose(np.asarray(o_layer), np.asarray(o_ref),
                               atol=2e-4)
