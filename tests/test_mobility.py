"""Mobility model (Eq. 1-2) + motion blur tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as stst

from repro.core.mobility import (KMH_100, MobilityModel, apply_motion_blur,
                                 motion_blur_kernel)


def test_velocities_within_truncation_bounds():
    m = MobilityModel()
    v = np.asarray(m.sample(jax.random.PRNGKey(0), 10_000))
    assert v.min() >= m.v_min - 1e-5
    assert v.max() <= m.v_max + 1e-5


def test_pdf_integrates_to_one():
    m = MobilityModel()
    grid = np.linspace(m.v_min, m.v_max, 20001)
    pdf = np.asarray(m.pdf(grid))
    integral = np.trapezoid(pdf, grid)
    np.testing.assert_allclose(integral, 1.0, atol=1e-3)


def test_pdf_zero_outside_bounds():
    m = MobilityModel()
    assert float(m.pdf(m.v_min - 1.0)) == 0.0
    assert float(m.pdf(m.v_max + 1.0)) == 0.0


def test_sample_mean_matches_truncated_mean():
    m = MobilityModel()
    v = np.asarray(m.sample(jax.random.PRNGKey(1), 50_000))
    grid = np.linspace(m.v_min, m.v_max, 20001)
    pdf = np.asarray(m.pdf(grid))
    mean_expected = np.trapezoid(pdf * grid, grid)
    np.testing.assert_allclose(v.mean(), mean_expected, atol=0.1)


@settings(max_examples=30, deadline=None)
@given(v=stst.floats(1.0, 60.0))
def test_blur_linear_in_velocity(v):
    m = MobilityModel()
    np.testing.assert_allclose(float(m.blur_level(v)), 0.58 * v, rtol=1e-6)


def test_blur_threshold_100kmh():
    m = MobilityModel()
    assert bool(m.is_blurred(KMH_100 + 0.1))
    assert not bool(m.is_blurred(KMH_100 - 0.1))


@settings(max_examples=20, deadline=None)
@given(v=stst.floats(0.0, 80.0))
def test_motion_blur_kernel_normalized(v):
    k = np.asarray(motion_blur_kernel(v))
    np.testing.assert_allclose(k.sum(), 1.0, rtol=1e-5)
    assert (k >= 0).all()


def test_faster_vehicle_blurs_more():
    key = jax.random.PRNGKey(0)
    img = jax.random.uniform(key, (2, 16, 16, 3))
    slow = apply_motion_blur(img, 5.0)
    fast = apply_motion_blur(img, 60.0)
    # blur removes high-frequency content: total variation along W drops
    def tv(x):
        return float(jnp.abs(jnp.diff(x, axis=2)).mean())
    assert tv(fast) < tv(slow) <= tv(img) + 1e-9


def test_zero_blur_preserves_image_shape_and_range():
    img = jnp.ones((1, 8, 8, 3)) * 0.5
    out = apply_motion_blur(img, 10.0)
    assert out.shape == img.shape
    np.testing.assert_allclose(np.asarray(out), 0.5, atol=1e-5)
