"""Layer-level unit tests: attention paths, rope, norms, ssm, rwkv, moe."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as stst

from repro.configs.base import get_config
from repro.models import layers as L


def test_rope_preserves_norm_and_relative_angles():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y = L.apply_rope(x, pos, 10_000.0)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.fold_in(key, 2), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.full((1, 1), i), 10_000.0)
        kj = L.apply_rope(k, jnp.full((1, 1), j), 10_000.0)
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot_at(3, 1), dot_at(7, 5), rtol=1e-4)


def test_flash_attention_matches_direct():
    """Flash path (custom-VJP, chunk-recompute) == direct softmax path,
    forward AND gradients."""
    key = jax.random.PRNGKey(1)
    B, S, H, KH, D = 2, 512, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KH, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    direct = L.attention_core(q, k, v, pos, pos, causal=True)  # S<2048: direct
    qg = q.reshape(B, S, KH, H // KH, D)
    flash = L.flash_attention(
        qg, k, v, pos.astype(jnp.float32), pos.astype(jnp.float32),
        jnp.asarray(L.BIG_WINDOW, jnp.float32), True, 1 / np.sqrt(D),
        0.0, 128).reshape(B, S, H, D)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(flash),
                               atol=2e-5)

    def loss_flash(q, k, v):
        qg = q.reshape(B, S, KH, H // KH, D)
        o = L.flash_attention(qg, k, v, pos.astype(jnp.float32),
                              pos.astype(jnp.float32),
                              jnp.asarray(L.BIG_WINDOW, jnp.float32), True,
                              1 / np.sqrt(D), 0.0, 128)
        return jnp.sum(o ** 2)

    def loss_direct(q, k, v):
        return jnp.sum(L.attention_core(q, k, v, pos, pos, causal=True) ** 2)

    g1 = jax.grad(loss_flash, (0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_direct, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_sliding_window_masks_old_positions():
    key = jax.random.PRNGKey(2)
    B, S, H, D = 1, 64, 2, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    # window W: output at position t must equal attention over only the
    # last W positions
    W = 8
    out_win = L.attention_core(q, k, v, pos, pos, causal=True, window=W)
    t = 40
    qs = q[:, t:t + 1]
    ks = k[:, t - W + 1:t + 1]
    vs = v[:, t - W + 1:t + 1]
    ps = pos[:, t - W + 1:t + 1]
    out_ref = L.attention_core(qs, ks, vs, pos[:, t:t + 1], ps, causal=True,
                               window=W)
    np.testing.assert_allclose(np.asarray(out_win[:, t]),
                               np.asarray(out_ref[:, 0]), atol=1e-5)


def test_attention_softcap_bounds_scores():
    """With softcap c, pre-softmax scores are bounded by c — check the
    output equals manual tanh-capped attention."""
    key = jax.random.PRNGKey(3)
    B, S, H, D = 1, 16, 1, 8
    q = jax.random.normal(key, (B, S, H, D)) * 10
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D)) * 10
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    cap = 5.0
    out = L.attention_core(q, k, v, pos, pos, causal=True, softcap=cap)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
    s = cap * jnp.tanh(s / cap)
    mask = pos[:, None, :, None] >= pos[:, None, None, :]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, -1)
    ref = jnp.einsum("bhqk,bkhd->bqhd", p, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_ring_buffer_cache_wraps_correctly():
    cfg = get_config("tinyllama-1.1b").reduced()
    key = jax.random.PRNGKey(4)
    p = L.init_attention(cfg, key)
    B, W = 1, 8
    cache = L.make_cache(cfg, B, W, jnp.float32, n_layers=0)
    # write 12 tokens one at a time; cache holds last 8
    for t in range(12):
        x = jax.random.normal(jax.random.fold_in(key, t), (B, 1, cfg.d_model))
        _, cache = L.attention_block(cfg, p, x, jnp.full((B, 1), t),
                                     window=W, cache=cache)
    pos = np.sort(np.asarray(cache["pos"][0]))
    np.testing.assert_array_equal(pos, np.arange(4, 12))


def test_rwkv_chunked_equals_decode_steps():
    cfg = get_config("rwkv6-1.6b").reduced()
    key = jax.random.PRNGKey(5)
    p = L.init_rwkv_tmix(cfg, key)
    B, S, d = 1, 24, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.2
    o_all, st_all, xl = L.rwkv_tmix_chunked(cfg, p, x)
    # token-by-token decode
    D = cfg.rwkv_head_dim
    H = d // D
    st = jnp.zeros((B, H, D, D))
    x_last = jnp.zeros((B, d))
    outs = []
    for t in range(S):
        o, st, x_last = L.rwkv_tmix_step(cfg, p, x[:, t:t + 1], st, x_last)
        outs.append(o)
    o_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_all), np.asarray(o_seq), atol=3e-5)
    np.testing.assert_allclose(np.asarray(st_all), np.asarray(st), atol=3e-5)


def test_ssm_chunked_equals_stepwise():
    cfg = get_config("hymba-1.5b").reduced()
    key = jax.random.PRNGKey(6)
    p = L.init_ssm(cfg, key)
    B, S, d = 1, 20, cfg.d_model
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, d)) * 0.2
    o_all, (h_all, cs_all) = L.ssm_block(cfg, p, x)
    h = None
    cs = None
    outs = []
    for t in range(S):
        o, (h, cs) = L.ssm_block(cfg, p, x[:, t:t + 1], state=h, conv_state=cs)
        outs.append(o)
    o_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(o_all), np.asarray(o_seq), atol=3e-5)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(h), atol=3e-5)


def test_int8_cache_decode_close():
    """Quantized KV cache (§Perf iteration 7): same top-1, small logit err."""
    from repro.models import transformer as T
    cfg = get_config("tinyllama-1.1b").reduced()
    key = jax.random.PRNGKey(11)
    p = T.init_params(cfg, key)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    full, _, _ = T.forward(cfg, p, toks)
    cache = T.init_cache(cfg, B, S + 1, dtype=jnp.int8)
    _, cache, _ = T.forward(cfg, p, toks[:, :S], mode="prefill", cache=cache)
    assert cache["kv"]["k"].dtype == jnp.int8
    dec, _, _ = T.forward(cfg, p, toks[:, S:S + 1], mode="decode",
                          cache=cache, positions=jnp.full((B,), S, jnp.int32))
    ref = full[:, -1, :cfg.vocab_size]
    got = dec[:, 0, :cfg.vocab_size]
    assert float(jnp.abs(ref - got).max()) < 0.25
    np.testing.assert_array_equal(np.asarray(ref.argmax(-1)),
                                  np.asarray(got.argmax(-1)))


def test_norms_match_definitions():
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (3, 5, 16)) * 3 + 1
    pr = L.init_rmsnorm(16)
    y = L.rmsnorm(pr, x)
    rms = np.sqrt((np.asarray(x) ** 2).mean(-1, keepdims=True) + 1e-5)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) / rms, rtol=1e-4)
    pl_ = L.init_layernorm(16)
    y2 = L.layernorm(pl_, x)
    np.testing.assert_allclose(np.asarray(y2).mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(y2).std(-1), 1.0, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(seed=stst.integers(0, 1000))
def test_moe_matches_dense_reference_when_no_drops(seed):
    cfg = dataclasses.replace(get_config("olmoe-1b-7b").reduced(),
                              moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(seed)
    p = L.init_moe(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model)) * 0.5
    y1, aux = L.moe_block(cfg, p, x)
    y2 = L.moe_block_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-5)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_bounded():
    """With capacity factor 1.0 some tokens drop; outputs stay finite."""
    cfg = dataclasses.replace(get_config("olmoe-1b-7b").reduced(),
                              moe_capacity_factor=1.0)
    key = jax.random.PRNGKey(9)
    p = L.init_moe(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, cfg.d_model))
    y1, _ = L.moe_block(cfg, p, x)
    assert bool(jnp.isfinite(y1).all())
