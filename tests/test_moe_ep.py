"""Expert-parallel MoE (shard_map all_to_all) correctness.

The EP path needs a real multi-device mesh, which requires forcing host
devices BEFORE jax initializes — so the mesh test runs in a subprocess;
the in-process tests cover the fallback logic.
"""
import subprocess
import sys
import textwrap

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.configs.base import get_config
from repro.models import layers as L


def test_ep_falls_back_without_mesh():
    """On the default 1-device environment moe_apply must route to the
    scatter implementation and agree with the dense oracle."""
    cfg = dataclasses.replace(get_config("olmoe-1b-7b").reduced(),
                              moe_capacity_factor=16.0, moe_impl="auto")
    key = jax.random.PRNGKey(0)
    p = L.init_moe(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model))
    y, aux = L.moe_apply(cfg, p, x)
    ref = L.moe_block_dense_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=2e-5)


def test_ep_matches_dense_ref_on_8_device_mesh():
    """Subprocess with 8 forced host devices: EP output == dense oracle."""
    prog = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.configs.base import get_config
        from repro.models import layers as L

        cfg = dataclasses.replace(get_config("olmoe-1b-7b").reduced(),
                                  moe_capacity_factor=16.0)
        key = jax.random.PRNGKey(0)
        p = L.init_moe(cfg, key)
        x = jax.random.normal(jax.random.fold_in(key, 1),
                              (4, 8, cfg.d_model)) * 0.5
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ref = L.moe_block_dense_ref(cfg, p, x)
        with compat.set_mesh(mesh):
            y, aux = jax.jit(lambda p, x: L.moe_block_ep(cfg, p, x))(p, x)
        err = float(jnp.abs(y - ref).max())
        assert err < 5e-5, err
        assert float(aux) >= 0.0
        print("EP-OK", err)
    """)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"},
                         cwd=__import__("os").path.join(
                             __import__("os").path.dirname(__file__), ".."))
    assert "EP-OK" in out.stdout, out.stderr[-2000:]


def test_ep_gated_off_at_low_token_count():
    """moe_apply(auto) must not choose EP when per-shard expert load < 1
    (the kimi decode regression from §Perf iteration 6)."""
    cfg = dataclasses.replace(get_config("kimi-k2-1t-a32b").reduced(),
                              moe_impl="auto")
    # T_loc * k / E with T=2*1, 1 shard, E=4, k=2 -> 1.0 boundary; use T=1
    key = jax.random.PRNGKey(1)
    p = L.init_moe(cfg, key)
    x = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, cfg.d_model))
    y, aux = L.moe_apply(cfg, p, x)  # must not raise; scatter path
    assert bool(jnp.isfinite(y).all())
