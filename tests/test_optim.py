"""Optimizer + schedule unit tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import adamw, cosine_schedule, constant_schedule, sgd


def test_sgd_momentum_matches_manual():
    init, update = sgd(momentum=0.9, weight_decay=0.0)
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    s = init(p)
    p1, s1 = update(p, g, s, lr=0.1)
    np.testing.assert_allclose(np.asarray(p1["w"]), [1 - 0.05, 2 + 0.05],
                               rtol=1e-6)
    p2, s2 = update(p1, g, s1, lr=0.1)
    # momentum: m2 = 0.9*0.5 + 0.5 = 0.95 per |g|
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               [0.95 - 0.095, 2.05 + 0.095], rtol=1e-6)


def test_sgd_weight_decay_shrinks_params():
    init, update = sgd(momentum=0.0, weight_decay=0.1)
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([0.0])}
    p1, _ = update(p, g, init(p), lr=0.5)
    np.testing.assert_allclose(np.asarray(p1["w"]), [1 - 0.5 * 0.1], rtol=1e-6)


def test_adamw_converges_on_quadratic():
    init, update = adamw(weight_decay=0.0)
    p = {"w": jnp.array([5.0, -3.0])}
    s = init(p)
    for _ in range(200):
        g = {"w": 2 * p["w"]}
        p, s = update(p, g, s, lr=0.05)
    assert float(jnp.abs(p["w"]).max()) < 0.1


def test_adamw_first_step_is_lr_sized():
    init, update = adamw()
    p = {"w": jnp.array([1.0])}
    g = {"w": jnp.array([0.3])}
    p1, _ = update(p, g, init(p), lr=0.01)
    # bias-corrected first step ~ lr * sign(g) (+wd)
    assert 0.005 < float((p["w"] - p1["w"])[0]) < 0.025


def test_cosine_schedule_endpoints():
    lr = cosine_schedule(0.9, 100)
    np.testing.assert_allclose(float(lr(0)), 0.9, rtol=1e-6)
    np.testing.assert_allclose(float(lr(100)), 0.0, atol=1e-6)
    assert float(lr(50)) == pytest.approx(0.45, rel=1e-3)
    # monotone decreasing
    vals = [float(lr(t)) for t in range(0, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_cosine_schedule_with_warmup():
    lr = cosine_schedule(1.0, 100, warmup=10)
    assert float(lr(5)) == pytest.approx(0.5, rel=1e-3)
    assert float(lr(10)) == pytest.approx(1.0, rel=1e-2)


def test_constant_schedule():
    lr = constant_schedule(0.3)
    assert float(lr(0)) == float(lr(1000)) == pytest.approx(0.3)


def test_optimizers_preserve_dtype():
    init, update = sgd()
    p = {"w": jnp.ones((3,), jnp.bfloat16)}
    g = {"w": jnp.ones((3,), jnp.bfloat16)}
    p1, _ = update(p, g, init(p), 0.1)
    assert p1["w"].dtype == jnp.bfloat16
