"""Serving tier (src/repro/serve/) acceptance tests.

Three layers:

  1. store — publish/chain/full-fallback semantics, encode-once
     accounting, eviction -> broken-chain -> full fallback, and BITWISE
     decode parity of every reply path against the published trees for
     all three codecs (lossy delta_int8 included: reconstruction
     chaining keeps server and vehicles in step);
  2. server — admission control (queue bound, shed-with-retry-after),
     batch coalescing (one reply build per distinct have_round),
     stop() draining semantics: no admitted request is ever lost;
  3. serve-while-training — N client threads fetch DURING a
     `run_campaign(publish=store.publish)`; every decoded tree is
     bitwise equal to some published `FLState` model, and the engine
     compile bounds (jit_round <= 1, scan <= 2) hold with the publish
     hook attached — serving adds zero device syncs to the compiled
     path.
"""
import threading

import jax
import numpy as np
import pytest

from repro.analysis.guards import assert_compile_bounds
from repro.core.engine import compile_counts, run_campaign
from repro.core.scenario import Scenario, run
from repro.serve import (ModelStore, RSUServer, ServePolicy, apply_reply,
                         build_reply)

CODEC_NAMES = ["identity", "delta", "delta_int8"]


def _tree_at(i, seed=0):
    k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
    ks = jax.random.split(k, 3)
    return {"w": jax.random.normal(ks[0], (3, 2)),
            "b": jax.random.normal(ks[1], (4,)),
            "s": jax.random.normal(ks[2], ())}


def _eq(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


def _scenario(rounds=3):
    rs = np.random.RandomState(0)
    data = [rs.rand(6, 4, 4, 3).astype(np.float32) for _ in range(8)]
    return Scenario(topology="single", data=data, n_vehicles=8,
                    vehicles_per_round=3, batch_size=2, rounds=rounds,
                    local_iters=1, lr=0.4, seed=11)


# --------------------------------------------------------------------------
# store
# --------------------------------------------------------------------------

@pytest.mark.parametrize("codec", CODEC_NAMES)
def test_publish_chain_decodes_bitwise(codec):
    store = ModelStore(codec=codec, window=8)
    for r in range(5):
        store.publish(r, _tree_at(r))
    # walk the whole chain from round 0 like a vehicle would
    tree = store.get(0).served_tree
    chain = store.chain_from(0)
    assert [s.round for s in chain] == [1, 2, 3, 4]
    from repro.comms.codecs import decode_snapshot
    for snap in chain:
        tree = decode_snapshot(codec, snap.delta_payload, tree)
        assert _eq(tree, snap.served_tree)
    if codec != "delta_int8":          # lossless: served IS the published
        assert _eq(tree, store.get(4).tree)


def test_publish_encodes_once_and_rounds_increase():
    store = ModelStore(codec="delta", window=8)
    for r in range(4):
        store.publish(r, _tree_at(r))
    st = store.stats()
    assert st == {"publishes": 4, "delta_encodes": 3, "full_encodes": 0}
    with pytest.raises(ValueError, match="increase"):
        store.publish(2, _tree_at(2))
    # full payload: built lazily, once, then cached
    store.full_payload(3)
    store.full_payload(3)
    assert store.stats()["full_encodes"] == 1
    with pytest.raises(KeyError):
        store.full_payload(99)


def test_eviction_breaks_chain_into_full_fallback():
    store = ModelStore(codec="delta", window=3)
    for r in range(6):
        store.publish(r, _tree_at(r))
    assert store.rounds() == [3, 4, 5]
    # a vehicle on an evicted round has no chain...
    assert store.chain_from(1) is None
    rep = build_reply(store, ServePolicy(max_lag=10), 1)
    assert rep.kind == "full" and rep.round == 5
    # ...and the full payload decodes bitwise to the published model
    assert _eq(apply_reply(rep, None), store.get(5).tree)
    # a retained round still chains
    chain = store.chain_from(3)
    assert [s.round for s in chain] == [4, 5]


@pytest.mark.parametrize("codec", CODEC_NAMES)
def test_reply_paths_bitwise_vs_served_tree(codec):
    store = ModelStore(codec=codec, window=8)
    for r in range(5):
        store.publish(r, _tree_at(r))
    pol = ServePolicy(max_lag=2)
    # delta within max_lag
    rep = build_reply(store, pol, 3)
    assert rep.kind == "delta" and rep.round == 4 and rep.base_round == 3
    assert _eq(apply_reply(rep, store.get(3).served_tree, codec=codec),
               store.get(4).served_tree)
    # too stale for the chain -> full, still bitwise
    rep = build_reply(store, pol, 0)
    assert rep.kind == "full"
    assert _eq(apply_reply(rep, None, codec=codec),
               store.get(4).served_tree)
    # up to date -> "current" carries no payload
    rep = build_reply(store, pol, 4)
    assert rep.kind == "current" and rep.payloads == ()
    marker = {"sentinel": jax.numpy.zeros((1,))}
    assert apply_reply(rep, marker, codec=codec) is marker


def test_empty_store_sheds_with_retry_after():
    store = ModelStore()
    rep = build_reply(store, ServePolicy(retry_after_s=0.25), 0)
    assert rep.status == "shed" and rep.retry_after_s == 0.25
    with pytest.raises(ValueError, match="shed"):
        apply_reply(rep, None)


# --------------------------------------------------------------------------
# server
# --------------------------------------------------------------------------

def _served_store(codec="delta", rounds=4):
    store = ModelStore(codec=codec, window=rounds + 2)
    for r in range(rounds):
        store.publish(r, _tree_at(r))
    return store


def test_admission_control_bounds_queue_and_sheds():
    server = RSUServer(_served_store(),
                       ServePolicy(queue_limit=8, retry_after_s=0.125),
                       start=False)
    pends = [server.submit(2) for _ in range(20)]
    # overflow requests resolved immediately as shed, with backpressure
    shed = [p for p in pends if p.done()]
    assert len(shed) == 12
    assert all(p.result().status == "shed" and
               p.result().retry_after_s == 0.125 for p in shed)
    assert server.stats()["max_depth"] == 8
    while server.drain_once(block=False):
        pass
    st = server.stats()
    assert st["submitted"] == 20 and st["served"] == 8 and st["shed"] == 12
    assert all(p.done() for p in pends)                     # zero lost


def test_batcher_coalesces_one_reply_per_have_round():
    server = RSUServer(_served_store(), ServePolicy(max_batch=64),
                       start=False)
    pends = [server.submit(r) for r in [2, 2, 2, 1, 1, 3]]
    assert server.drain_once(block=False) == 6
    st = server.stats()
    assert st["batches"] == 1 and st["groups"] == 3
    # coalesced requests share the SAME reply object
    assert pends[0].result() is pends[1].result() is pends[2].result()
    assert pends[3].result() is pends[4].result()
    # full_payload built at most once however many stale fetchers
    store = _served_store()
    server2 = RSUServer(store, ServePolicy(max_lag=0), start=False)
    for _ in range(5):
        server2.submit(0)
    server2.drain_once(block=False)
    assert store.stats()["full_encodes"] == 1


def test_max_batch_splits_drains():
    server = RSUServer(_served_store(), ServePolicy(max_batch=4),
                       start=False)
    for _ in range(10):
        server.submit(2)
    drained = []
    while True:
        n = server.drain_once(block=False)
        if not n:
            break
        drained.append(n)
    assert drained == [4, 4, 2]


def test_fetch_answered_exactly_once():
    from repro.serve import PendingFetch, Reply
    p = PendingFetch(0)
    p._resolve(Reply(status="ok", kind="current", round=0))
    with pytest.raises(RuntimeError, match="twice"):
        p._resolve(Reply(status="ok", kind="current", round=0))
    with pytest.raises(TimeoutError):
        PendingFetch(0).result(timeout=0.01)


def test_stop_drains_pending_then_sheds_new_submits():
    server = RSUServer(_served_store(), start=False)
    pends = [server.submit(2) for _ in range(5)]
    server.stop(drain=True)
    assert all(p.result().status == "ok" for p in pends)
    late = server.submit(2)                     # after stop: immediate shed
    assert late.result().status == "shed"
    server2 = RSUServer(_served_store(), start=False)
    pends2 = [server2.submit(2) for _ in range(5)]
    server2.stop(drain=False)
    assert all(p.result().status == "shed" for p in pends2)
    st = server2.stats()
    assert st["submitted"] == 5 and st["shed"] == 5 and st["served"] == 0


def test_threaded_server_serves_concurrent_fleet():
    store = _served_store()
    server = RSUServer(store, ServePolicy(max_wait_s=0.002))
    results = []

    def fleet(seed):
        rs = np.random.RandomState(seed)
        got = []
        for _ in range(25):
            have = int(rs.randint(0, 4))
            rep = server.submit(have).result(timeout=10.0)
            assert rep.status == "ok"
            base = store.get(have).served_tree
            got.append(_eq(apply_reply(rep, base), store.get(3).served_tree))
        results.append(got)

    threads = [threading.Thread(target=fleet, args=(i,)) for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    server.stop()
    assert all(all(r) for r in results)
    st = server.stats()
    assert st["submitted"] == st["served"] == 150 and st["shed"] == 0


# --------------------------------------------------------------------------
# serve-while-training
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["jit", "scan"])
def test_serve_during_campaign_bitwise_and_compile_bounds(mode):
    sc = _scenario(rounds=4)
    store = ModelStore(codec="delta", window=8)
    state0 = sc.init_state()
    store.publish(state0.round, state0.global_tree)
    published = {0: state0.global_tree}

    def publish(rnd, tree):
        published[int(rnd)] = tree
        store.publish(rnd, tree)

    server = RSUServer(store, ServePolicy(max_lag=8, max_wait_s=0.001))
    stop_flag = threading.Event()
    out = []

    def vehicle(seed):
        rs = np.random.RandomState(seed)
        checked = 0
        while not (stop_flag.is_set() and checked):
            have = int(rs.choice(store.rounds()))
            base = store.get(have)
            rep = server.submit(have).result(timeout=30.0)
            if rep.status != "ok" or base is None:
                continue
            tree = apply_reply(rep, base.served_tree)
            snap = store.get(rep.round)
            if snap is not None:            # not evicted meanwhile
                assert rep.round >= have
                assert _eq(tree, snap.served_tree)
                checked += 1
        out.append(checked)

    threads = [threading.Thread(target=vehicle, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    state, hist = run_campaign(sc, state0, mode=mode, publish=publish,
                               publish_every=1)
    stop_flag.set()
    for t in threads:
        t.join()
    server.stop()

    # every published snapshot's tree IS an FLState model, bitwise
    assert sorted(published) == [0, 1, 2, 3, 4]
    assert _eq(published[4], state.global_tree)
    for rnd, tree in published.items():
        snap = store.get(rnd)
        if snap is not None:
            assert _eq(snap.tree, tree)
    # the fleet actually fetched, nothing was lost
    assert all(n > 0 for n in out)
    st = server.stats()
    assert st["submitted"] == st["served"] + st["shed"]
    # publish hook adds no programs: the engine bounds still hold
    assert_compile_bounds(compile_counts(sc), what=f"serve+{mode} campaign")


def test_eager_run_publish_hook_matches_campaign_schedule():
    sc = _scenario(rounds=3)
    seen = []
    state, _ = run(sc, publish=lambda r, t: seen.append((int(r), t)))
    assert [r for r, _ in seen] == [1, 2, 3]
    assert _eq(seen[-1][1], state.global_tree)


def test_publish_every_chunks_campaign():
    sc = _scenario(rounds=4)
    seen = []
    state, _ = run_campaign(sc, publish=lambda r, t: seen.append(int(r)),
                            publish_every=2)
    assert seen == [2, 4]
    with pytest.raises(ValueError, match="publish_every"):
        run_campaign(sc, publish_every=-1)
