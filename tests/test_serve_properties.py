"""Property tests for the serving tier (tier-1, hypothesis-driven).

Random publish/submit/drain interleavings against a deterministic
queue model — always the same invariants:

  * accounting — no request is ever lost (every handle resolves after
    the final drain) or answered twice (`PendingFetch._resolve` raises;
    any double-resolution would abort the sequence);
  * admission — the queue never holds more than ``queue_limit``
    requests, and the shed count equals the reference model's
    prediction exactly;
  * freshness — every served reply's round is the requested round or
    newer (equal only via the "current" kind);
  * parity — every served payload (delta chain, full staleness
    fallback, or "current") decodes BITWISE equal to the store's
    reconstruction for the reply's round, for lossless AND lossy
    codecs — random interleavings never fork the fleet.

hypothesis is a dev-only dependency; the module skips when absent, like
tests/test_cohort_properties.py.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve import ModelStore, RSUServer, ServePolicy, apply_reply

SETTINGS = settings(max_examples=30, deadline=None)

CODEC_NAMES = ["identity", "delta", "delta_int8"]


def _tree_at(i, seed=0):
    k = jax.random.fold_in(jax.random.PRNGKey(seed), i)
    ks = jax.random.split(k, 2)
    return {"w": jax.random.normal(ks[0], (3, 2)),
            "b": jax.random.normal(ks[1], (4,))}


def _eq(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(np.asarray(x), np.asarray(y)) for x, y in zip(la, lb))


# one op sequence: publish next round / submit a fetch / drain a batch
_OPS = st.lists(
    st.one_of(
        st.just(("publish",)),
        st.tuples(st.just("submit"), st.integers(min_value=-1, max_value=12)),
        st.just(("drain",)),
    ),
    min_size=1, max_size=40)


@SETTINGS
@given(ops=_OPS,
       queue_limit=st.integers(min_value=1, max_value=6),
       max_batch=st.integers(min_value=1, max_value=8),
       max_lag=st.integers(min_value=0, max_value=4),
       window=st.integers(min_value=1, max_value=6),
       codec=st.sampled_from(CODEC_NAMES))
def test_interleavings_preserve_queue_and_parity_invariants(
        ops, queue_limit, max_batch, max_lag, window, codec):
    store = ModelStore(codec=codec, window=window)
    policy = ServePolicy(max_batch=max_batch, queue_limit=queue_limit,
                         max_lag=max_lag, retry_after_s=0.01)
    server = RSUServer(store, policy, start=False)

    served_trees = {}          # round -> reconstruction (store evicts)
    next_round = 0
    model_queue = 0            # reference queue-depth model
    model_shed = 0
    pending = []               # (handle, have_round) in submit order

    for op in ops:
        if op[0] == "publish":
            snap = store.publish(next_round, _tree_at(next_round))
            served_trees[next_round] = snap.served_tree
            next_round += 1
        elif op[0] == "submit":
            # vehicles hold an already-published round, or -1 (never
            # fetched); a claimed-future round would legitimately get
            # "current" at the server's latest, breaking the
            # requested-or-newer invariant this test pins
            have = min(op[1], next_round - 1) if next_round else -1
            p = server.submit(have)
            pending.append((p, have))
            if model_queue >= queue_limit:
                model_shed += 1
                assert p.done() and p.result().status == "shed"
                assert p.result().retry_after_s == policy.retry_after_s
            else:
                model_queue += 1
        else:
            n = server.drain_once(block=False)
            assert n == min(model_queue, max_batch)
            model_queue -= n
        assert server.pending <= queue_limit

    # final drain: whatever is still queued must be answered
    while server.drain_once(block=False):
        pass

    st_ = server.stats()
    assert st_["submitted"] == len(pending)
    assert st_["shed"] == model_shed
    assert st_["served"] + st_["shed"] == len(pending)   # zero lost
    assert st_["max_depth"] <= queue_limit

    for p, have in pending:
        assert p.done()                                  # no request lost
        rep = p.result()
        if rep.status == "shed":
            assert rep.retry_after_s > 0
            continue
        # requested-or-newer round
        assert rep.round >= have
        if rep.round == have:
            assert rep.kind == "current"
        if rep.kind == "delta":
            assert rep.base_round == have
            assert len(rep.payloads) <= max_lag
        # parity: decode bitwise against the recorded reconstruction
        # (the store may have evicted the round since — served_trees
        # remembers every publish)
        base = served_trees.get(have)
        if rep.kind != "full" and base is None:
            continue            # "current" for a never-held round id
        tree = apply_reply(rep, base, codec=codec)
        if rep.kind != "current":
            assert _eq(tree, served_trees[rep.round])

    # exactly-once: resolving any handle again must raise
    from repro.serve import Reply
    for p, _ in pending[:3]:
        with pytest.raises(RuntimeError, match="twice"):
            p._resolve(Reply(status="ok", kind="current", round=0))


@SETTINGS
@given(rounds=st.integers(min_value=2, max_value=8),
       have=st.integers(min_value=0, max_value=7),
       codec=st.sampled_from(CODEC_NAMES))
def test_stale_fallback_decodes_bit_identical(rounds, have, codec):
    have = min(have, rounds - 1)
    store = ModelStore(codec=codec, window=rounds + 1)
    for r in range(rounds):
        store.publish(r, _tree_at(r, seed=3))
    # max_lag=0 forces EVERY behind-vehicle onto the full-tree fallback
    server = RSUServer(store, ServePolicy(max_lag=0), start=False)
    p = server.submit(have)
    server.drain_once(block=False)
    rep = p.result()
    latest = store.latest()
    if have >= latest.round:
        assert rep.kind == "current"
    else:
        assert rep.kind == "full"
        assert _eq(apply_reply(rep, None, codec=codec), latest.served_tree)
        if codec != "delta_int8":
            assert _eq(apply_reply(rep, None, codec=codec), latest.tree)


@SETTINGS
@given(hops=st.integers(min_value=1, max_value=6),
       codec=st.sampled_from(CODEC_NAMES))
def test_delta_chain_consistency_any_depth(hops, codec):
    """A vehicle applying the chain hop by hop lands BITWISE on the
    server-side reconstruction, however long the chain — lossy codecs
    included (snapshots chain off the reconstruction, not the exact
    tree, so decode determinism is the only requirement)."""
    store = ModelStore(codec=codec, window=hops + 2)
    for r in range(hops + 1):
        store.publish(r, _tree_at(r, seed=7))
    server = RSUServer(store, ServePolicy(max_lag=hops), start=False)
    p = server.submit(0)
    server.drain_once(block=False)
    rep = p.result()
    assert rep.kind == "delta" and len(rep.payloads) == hops
    tree = apply_reply(rep, store.get(0).served_tree, codec=codec)
    assert _eq(tree, store.get(hops).served_tree)
