"""Multi-device harness driver + tier-1-safe cohort-mesh unit tests.

The tier-1 process sees exactly ONE CPU device (tests/conftest.py sets
no XLA_FLAGS and imports jax, so forcing is impossible in-process). This
module makes the sharded paths run on 1-CPU CI anyway: it probes whether
`XLA_FLAGS=--xla_force_host_platform_device_count=8` can force 8 host
devices in a FRESH interpreter, and when it can, runs the whole
tests/multidevice/ suite in that subprocess — skipping cleanly when
forcing is unavailable (e.g. a jax build without the host-platform
flag). The pure mesh-sizing helpers and the actionable error messages
of launch/mesh.py are tested here directly; they need no devices.
"""
import os
import subprocess
import sys

import pytest

from repro.launch.mesh import _FORCE_HINT, cohort_axis_divisor, cohort_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FORCE = "--xla_force_host_platform_device_count=8"


def _forced_env():
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " " + FORCE).strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), env.get("PYTHONPATH", "")]).rstrip(
            os.pathsep)
    return env


def _forced_device_count() -> int:
    try:
        out = subprocess.run(
            [sys.executable, "-c", "import jax; print(jax.device_count())"],
            env=_forced_env(), capture_output=True, text=True, timeout=120)
    except (OSError, subprocess.TimeoutExpired):
        return 0
    if out.returncode != 0:
        return 0
    try:
        return int(out.stdout.strip().splitlines()[-1])
    except (ValueError, IndexError):
        return 0


# --------------------------------------------------------------------------
# tier-1-safe: mesh sizing + actionable errors (no devices needed)
# --------------------------------------------------------------------------

def test_cohort_axis_divisor_policy():
    # largest d | rows_per_pod with pods*d <= devices
    assert cohort_axis_divisor(4, 2, device_count=8) == 4
    assert cohort_axis_divisor(6, 2, device_count=8) == 3
    assert cohort_axis_divisor(5, 2, device_count=8) == 1   # 5 is prime > cap
    assert cohort_axis_divisor(8, 2, device_count=8) == 4
    assert cohort_axis_divisor(7, 1, device_count=8) == 7
    assert cohort_axis_divisor(4, 16, device_count=8) == 1  # cap floors at 1


def test_cohort_mesh_actionable_errors():
    with pytest.raises(ValueError, match=">= 1"):
        cohort_mesh(0, 4)
    import jax
    need = jax.device_count() + 1
    # required vs available counts AND the forcing hint, not a bare error
    with pytest.raises(ValueError) as ei:
        cohort_mesh(need, 1)
    msg = str(ei.value)
    assert f"needs {need} devices" in msg
    assert f"have {jax.device_count()}" in msg
    assert "xla_force_host_platform_device_count" in msg
    assert _FORCE_HINT in msg


def test_multi_rsu_uneven_cohort_error_is_actionable():
    from repro.core.state import FLConfig
    from repro.core.topology import MultiRSU
    cfg = FLConfig(vehicles_per_round=5)
    with pytest.raises(ValueError) as ei:
        MultiRSU(n_rsus=2, mesh_aggregate=True).resolve_mesh(cfg)
    msg = str(ei.value)
    assert "mesh_aggregate" in msg and "not divisible" in msg
    assert "auto-fall-back" in msg                     # the uneven hint
    # auto mode falls back silently instead
    assert MultiRSU(n_rsus=2).resolve_mesh(cfg) is None


# --------------------------------------------------------------------------
# the forced-8-device subprocess session
# --------------------------------------------------------------------------

@pytest.mark.slow
def test_multidevice_suite_under_forced_devices():
    """Run tests/multidevice/ in a subprocess with 8 forced host devices
    — the acceptance gate for every sharded bit-exactness contract."""
    forced = _forced_device_count()
    if forced < 8:
        pytest.skip(f"cannot force 8 host devices (probe saw {forced}); "
                    "sharded contracts run in the CI multidevice job")
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "tests/multidevice", "-q",
         "--no-header", "-p", "no:cacheprovider"],
        env=_forced_env(), cwd=REPO, capture_output=True, text=True,
        timeout=3000)
    tail = "\n".join((proc.stdout + proc.stderr).splitlines()[-30:])
    assert proc.returncode == 0, f"multidevice suite failed:\n{tail}"
    assert "passed" in proc.stdout
