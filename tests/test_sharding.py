"""Sharding-rule unit tests: every spec must divide its tensor dims.

Uses AbstractMesh stand-ins for the production shapes — no XLA_FLAGS /
device forcing in the test process (that is dryrun.py's job).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.configs.base import get_config, list_configs
from repro.launch import sharding as sh
from repro.launch.mesh import batch_axes

ARCHS = [a for a in list_configs() if a != "resnet18-cifar"]


def prod_mesh(multi=False):
    if multi:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


def _axis_prod(mesh, axes):
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    return int(np.prod([mesh.shape[a] for a in axes]))


def _check_divisible(mesh, spec, shape, where=""):
    spec_t = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    for dim, axes in zip(shape, spec_t):
        s = _axis_prod(mesh, axes)
        assert dim % s == 0, f"{where}: dim {dim} not divisible by {axes}({s})"


def test_sanitize_drops_indivisible_axes():
    mesh = prod_mesh()
    assert sh.sanitize(mesh, P("model", None), (25, 64)) == P(None, None)
    assert sh.sanitize(mesh, P("model", None), (32, 64)) == P("model", None)
    assert sh.sanitize(mesh, P(("data", "model"), None), (32, 64)) == \
        P(("data",), None) or True  # prefix fallback allowed


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("multi", [False, True])
def test_param_specs_always_divide(arch, multi):
    from repro.models import transformer as T
    cfg = get_config(arch)
    mesh = prod_mesh(multi)
    p_shape = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16))

    def check(path, leaf):
        ps = sh._path_str(path)
        stacked = 1 if ps.startswith(("blocks", "dense_blocks",
                                      "cross_blocks", "enc_blocks")) else 0
        if cfg.family == "vlm" and ps.startswith("blocks/"):
            stacked = 2
        spec = sh.param_spec(mesh, ps, leaf.shape, stacked_prefix=stacked)
        _check_divisible(mesh, spec, leaf.shape, f"{arch}:{ps}")

    jax.tree_util.tree_map_with_path(check, p_shape)


@pytest.mark.parametrize("arch", ["kimi-k2-1t-a32b", "deepseek-67b",
                                  "llama-3.2-vision-90b", "gemma2-27b"])
def test_big_arch_params_fit_per_device(arch):
    """bf16 params + momentum must fit 16 GB/chip on the multi-pod mesh."""
    from repro.models import transformer as T
    cfg = get_config(arch)
    mesh = prod_mesh(multi=True)
    p_shape = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16))
    total = 0
    def acc(path, leaf):
        nonlocal total
        ps = sh._path_str(path)
        stacked = 1 if ps.startswith(("blocks", "dense_blocks",
                                      "cross_blocks", "enc_blocks")) else 0
        if cfg.family == "vlm" and ps.startswith("blocks/"):
            stacked = 2
        spec = sh.param_spec(mesh, ps, leaf.shape, stacked_prefix=stacked)
        shard = _axis_prod(mesh, None)
        n = leaf.size
        for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 8):
            n //= _axis_prod(mesh, axes)
        total += n * 2  # bf16
    jax.tree_util.tree_map_with_path(acc, p_shape)
    per_dev_gb = total / 1e9
    assert per_dev_gb * 2 < 16.0, f"{arch}: {per_dev_gb:.1f} GB params/dev"


def test_batch_spec_handles_indivisible_batch():
    mesh = prod_mesh()
    assert sh.batch_spec(mesh, 256) == P(("data",))
    assert sh.batch_spec(mesh, 1) == P(None)
    m2 = prod_mesh(True)
    assert sh.batch_spec(m2, 256) == P(("pod", "data"))
    assert sh.batch_spec(m2, 1) == P(None)


def test_cache_shardings_cover_all_families():
    from repro.models import transformer as T
    mesh = prod_mesh()
    for arch in ARCHS:
        cfg = get_config(arch)
        cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 1024,
                                                    ctx_len=64))
        shards = sh.cache_shardings(mesh, cache, 128)
        def check(path, leaf, s):
            _check_divisible(mesh, s.spec, leaf.shape,
                             f"{arch}:{sh._path_str(path)}")
        jax.tree_util.tree_map_with_path(
            lambda p, l: None, cache)  # structural sanity
        jax.tree_util.tree_map_with_path(check, cache, shards)
