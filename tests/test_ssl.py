"""SSL augmentation + MoCo machinery tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ssl


def test_pi1_pi2_preserve_shape_and_range():
    key = jax.random.PRNGKey(0)
    x = jax.random.uniform(key, (8, 16, 16, 3))
    v1 = ssl.pi1(jax.random.fold_in(key, 1), x)
    v2 = ssl.pi2(jax.random.fold_in(key, 2), x)
    assert v1.shape == x.shape and v2.shape == x.shape
    assert float(v2.min()) >= 0.0 and float(v2.max()) <= 1.0
    assert bool(jnp.isfinite(v1).all() and jnp.isfinite(v2).all())


def test_views_differ_from_original_and_each_other():
    key = jax.random.PRNGKey(1)
    x = jax.random.uniform(key, (16, 8, 8, 3))
    v1 = ssl.pi1(jax.random.fold_in(key, 1), x)
    v2 = ssl.pi2(jax.random.fold_in(key, 2), x)
    assert not np.allclose(np.asarray(v1), np.asarray(v2))


def test_grayscale_makes_channels_equal():
    x = jax.random.uniform(jax.random.PRNGKey(2), (2, 4, 4, 3))
    g = ssl._grayscale(x)
    np.testing.assert_allclose(np.asarray(g[..., 0]), np.asarray(g[..., 1]))
    np.testing.assert_allclose(np.asarray(g[..., 1]), np.asarray(g[..., 2]))


def test_momentum_update_ema():
    p = {"w": jnp.ones((3,))}
    q = {"w": jnp.zeros((3,))}
    out = ssl.momentum_update(p, q, m=0.9)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.9)


def test_queue_push_ring_semantics():
    key = jax.random.PRNGKey(3)
    state = ssl.init_moco_state({}, queue_len=8, dim=4, key=key)
    k1 = jnp.ones((5, 4))
    state = ssl.queue_push(state, k1)
    assert int(state.ptr) == 5
    k2 = 2 * jnp.ones((5, 4))
    state = ssl.queue_push(state, k2)          # wraps: 5..7 then 0..1
    assert int(state.ptr) == 2
    q = np.asarray(state.queue)
    np.testing.assert_allclose(q[5:8], 2.0)
    np.testing.assert_allclose(q[0:2], 2.0)
    np.testing.assert_allclose(q[2:5], 1.0)


def test_fedco_merge_truncates_to_queue_length():
    gq = jnp.zeros((8, 4))
    ks = [jnp.ones((3, 4)), 2 * jnp.ones((3, 4))]
    out = ssl.fedco_merge_queues(gq, ks)
    assert out.shape == (8, 4)
    np.testing.assert_allclose(np.asarray(out[:3]), 1.0)
    np.testing.assert_allclose(np.asarray(out[3:6]), 2.0)
    np.testing.assert_allclose(np.asarray(out[6:]), 0.0)


def test_token_view_masks_expected_fraction():
    key = jax.random.PRNGKey(4)
    toks = jnp.full((64, 128), 7, jnp.int32)
    v = ssl.token_view(key, toks, mask_id=0, drop_p=0.25)
    frac = float((v == 0).mean())
    assert 0.15 < frac < 0.35
