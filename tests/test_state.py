"""Pure-round API: FLState purity, host-RNG decoupling, and bit-exact
checkpoint/resume for every topology and the FedCo client.

These are the acceptance tests of the functional redesign: `run_round`
must be a pure function of (FLState, Scenario), and saving the state at
round k then restoring must continue bit-identically to a run that never
paused — model tree, RNG streams, topology state, FedCo queue, and the
round records all included.
"""
import os

import jax
import numpy as np
import pytest

from repro.checkpoint.store import restore_state, save_state
from repro.core.scenario import Scenario, run, run_round
from repro.core.state import FLState, pack_host_rng, unpack_host_rng

# tiny-world scenario kwargs shared by every case (CPU-friendly)
TINY = dict(partitioner="iid", n_per_class=20, n_vehicles=6,
            batch_size=8, rounds=10, local_iters=1, lr=0.4, seed=11)

CASES = {
    "single": dict(topology="single", vehicles_per_round=2),
    "multi": dict(topology="multi", topology_kwargs={"n_rsus": 2},
                  vehicles_per_round=4),
    "handover": dict(topology="handover",
                     topology_kwargs={"n_rsus": 2, "rsu_range": 200.0,
                                      "round_duration": 50.0,
                                      "sync_every": 2},
                     vehicles_per_round=3),
    "fedco": dict(topology="single", client="fedco", aggregator="fedavg",
                  queue_len=64, vehicles_per_round=2),
}


def _scenario(case: str) -> Scenario:
    return Scenario(**{**TINY, **CASES[case]})


def _assert_states_identical(s1: FLState, s2: FLState):
    l1, l2 = jax.tree.leaves(s1.to_tree()), jax.tree.leaves(s2.to_tree())
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s1.round == s2.round


def test_run_round_is_pure():
    """Same FLState in -> same FLState out, and the input is untouched."""
    sc = _scenario("single")
    state = sc.init_state()
    before = [np.asarray(l).copy() for l in jax.tree.leaves(state.to_tree())]
    s1, r1 = run_round(state, sc)
    s2, r2 = run_round(state, sc)
    assert r1 == r2
    _assert_states_identical(s1, s2)
    # the input state was not mutated by either call
    for a, b in zip(before, jax.tree.leaves(state.to_tree())):
        np.testing.assert_array_equal(a, np.asarray(b))
    assert s1.round == state.round + 1


def test_host_rng_is_state_not_hidden():
    """Cohort/batch draws come from FLState.host_rng, not trainer-object
    RNG: two runs from the same mid-training state draw the same cohorts
    (velocities identify the cohort draw)."""
    sc = _scenario("single")
    state, _ = run_round(sc.init_state(), sc)
    _, r1 = run_round(state, sc)
    _, r2 = run_round(state, sc)
    assert r1["velocities"] == r2["velocities"]
    # and the host stream actually advanced across the first round
    rng0 = unpack_host_rng(sc.init_state().host_rng)
    rng1 = unpack_host_rng(state.host_rng)
    assert not np.array_equal(rng0.get_state()[1], rng1.get_state()[1]) or \
        rng0.get_state()[2] != rng1.get_state()[2]


def test_host_rng_pack_roundtrip():
    rng = np.random.RandomState(3)
    rng.choice(100, size=7)                      # advance the stream
    twin = unpack_host_rng(pack_host_rng(rng))
    np.testing.assert_array_equal(rng.choice(1000, size=50),
                                  twin.choice(1000, size=50))


@pytest.mark.parametrize("seed", range(10))
def test_host_rng_pack_roundtrip_property(seed):
    """Property (seed sweep): pack/unpack is the identity on the FULL
    MT19937 state at arbitrary points mid-stream — including the cached
    second gaussian, which `normal` draws leave behind and a lossy pack
    would silently drop."""
    draws = [lambda r: r.choice(50, size=5, replace=False),
             lambda r: r.rand(3),
             lambda r: r.normal(size=3),     # sets the gauss cache
             lambda r: r.permutation(17),
             lambda r: r.normal(size=2)]
    rng = np.random.RandomState(seed)
    for draw in draws:
        draw(rng)
        twin = unpack_host_rng(pack_host_rng(rng))
        s1, s2 = rng.get_state(legacy=True), twin.get_state(legacy=True)
        assert s1[0] == s2[0]
        np.testing.assert_array_equal(s1[1], s2[1])
        assert s1[2:] == s2[2:]
        # and the futures coincide, not just the snapshots
        np.testing.assert_array_equal(draw(rng), draw(twin))
        rng = twin                            # continue from the copy


@pytest.mark.parametrize("case", sorted(CASES))
def test_resume_is_bit_exact(case, tmp_path):
    """10 rounds straight == 5 rounds + save + restore + 5 rounds, down to
    the last bit of every FLState leaf and every history record."""
    sc = _scenario(case)
    straight, hist_straight = run(sc, rounds=10)

    mid, hist_a = run(sc, rounds=5)
    path = save_state(os.path.join(tmp_path, "ckpt_5.npz"), mid)
    restored = restore_state(path)
    assert restored.round == 5
    _assert_states_identical(mid, restored)
    resumed, hist_b = run(sc, restored, rounds=5)

    _assert_states_identical(straight, resumed)
    assert hist_straight == hist_a + hist_b


def test_fedco_state_lives_in_flstate():
    """The FedCo key-tree + queue are FLState fields, not trainer
    attributes; the queue round-trips through the checkpoint."""
    sc = _scenario("fedco")
    state = sc.init_state()
    assert set(state.client_state) == {"key_tree", "queue"}
    state2, _ = run_round(state, sc)
    q0 = np.asarray(state.client_state["queue"])
    q1 = np.asarray(state2.client_state["queue"])
    assert q1.shape == q0.shape
    assert not np.allclose(q0, q1)


def test_trainer_shim_matches_pure_api():
    """FederatedTrainer is a veneer: it must reproduce the pure API's
    states and records exactly."""
    from repro.core.federation import FederatedTrainer
    sc = _scenario("single")
    state, hist = run(sc, rounds=2)
    tr = FederatedTrainer(sc.cfg, sc.init_tree(), sc.data)
    tr.run(rounds=2, log_every=0)
    assert tr.history == hist
    _assert_states_identical(tr.state, state)
    with pytest.raises(ValueError, match="round index"):
        tr.round(7)


def test_scenario_validation():
    with pytest.raises(ValueError, match="topology"):
        Scenario(topology="nope")
    with pytest.raises(ValueError, match="partitioner"):
        Scenario(partitioner="nope")
    with pytest.raises(ValueError, match="aggregator"):
        Scenario(aggregator="nope")
    with pytest.raises(ValueError, match="client"):
        Scenario(client="nope")
    # handover forbids client algorithms with global server state
    with pytest.raises(ValueError, match="dtssl"):
        Scenario(topology="handover", client="fedco", aggregator="flsimco")
    # the legacy fedco alias must not silently override an explicit client
    with pytest.raises(ValueError, match="legacy alias"):
        Scenario(aggregator="fedco", client="dtssl")
    assert Scenario(aggregator="fedco").cfg.client == "fedco"
    assert Scenario(aggregator="fedco", client="fedco").cfg.client == "fedco"


def test_fedco_alias_resolved_once_for_both_entry_points():
    """`resolve_fedco_alias` is the single place the legacy spelling is
    normalized: FLConfig and Scenario must agree on acceptance AND on
    the conflict error, so the rule cannot drift between entry points."""
    from repro.core.state import FLConfig, resolve_fedco_alias

    assert resolve_fedco_alias("fedco", None) == ("fedavg", "fedco")
    assert resolve_fedco_alias("fedco", "fedco") == ("fedavg", "fedco")
    assert resolve_fedco_alias("flsimco", "dtssl") == ("flsimco", "dtssl")
    assert resolve_fedco_alias(None, None) == (None, None)
    with pytest.raises(ValueError, match="legacy alias"):
        resolve_fedco_alias("fedco", "dtssl")

    cfg = FLConfig(aggregator="fedco")
    assert (cfg.aggregator, cfg.client) == ("fedavg", "fedco")
    with pytest.raises(ValueError, match="legacy alias"):
        FLConfig(aggregator="fedco", client="dtssl")
    sc = Scenario(aggregator="fedco", queue_len=64)
    assert (sc.cfg.aggregator, sc.cfg.client) == ("fedavg", "fedco")
    # the alias also resolves when layered onto a pre-built cfg whose
    # client field is already normalized to a concrete name
    assert Scenario(FLConfig(queue_len=64),
                    aggregator="fedco").cfg.client == "fedco"
