"""End-to-end behaviour tests for the paper's system.

The full FLSimCo pipeline at miniature scale: synthetic data -> federated
DT-SSL pre-training -> kNN probe, plus the launch-layer train/serve steps
on the host mesh for a reduced architecture.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs.base import INPUT_SHAPES, InputShape, get_config
from repro.core.federation import FLConfig, FederatedTrainer
from repro.data.synthetic import make_dataset, partition_dirichlet
from repro.eval.probe import encode, knn_top1
from repro.launch import steps as st
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.models.resnet import init_resnet


@pytest.mark.slow
def test_flsimco_pipeline_learns_representations():
    """A few FLSimCo rounds must beat a random encoder on the kNN probe."""
    x, y = make_dataset(n_per_class=80, seed=0)
    split = int(0.8 * len(x))
    xtr, ytr, xte, yte = x[:split], y[:split], x[split:], y[split:]
    parts = partition_dirichlet(ytr, 6, alpha=1.0, min_per_client=30, seed=0)
    tree0 = init_resnet(get_config("resnet18-cifar"), jax.random.PRNGKey(0))

    f_tr0 = encode(tree0, xtr[:400])
    f_te0 = encode(tree0, xte[:200])
    acc0 = knn_top1(f_tr0, ytr[:400], f_te0, yte[:200])

    cfg = FLConfig(n_vehicles=6, vehicles_per_round=3, batch_size=64,
                   rounds=8, local_iters=1, lr=0.2, seed=0)
    tr = FederatedTrainer(cfg, tree0, [xtr[p] for p in parts])
    tr.run(log_every=0)

    f_tr = encode(tr.global_tree, xtr[:400])
    f_te = encode(tr.global_tree, xte[:200])
    acc1 = knn_top1(f_tr, ytr[:400], f_te, yte[:200])
    # random-encoder kNN on this dataset is already decent; training must
    # not destroy it and should typically improve it
    assert acc1 > acc0 - 0.05
    assert acc1 > 1.5 / 10  # far above chance


def test_launch_train_step_runs_on_host_mesh():
    cfg = get_config("olmoe-1b-7b").reduced()
    mesh = make_host_mesh()
    shape = InputShape("t", 32, 4, "train")
    fn, nm = st.make_train_step(cfg, shape, mesh, objective="lm", n_micro=2)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    mom = st.init_momentum(params)
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
             "blur": jnp.array([1.0, 2.0, 3.0, 4.0])}
    with compat.set_mesh(mesh):
        p2, m2, metrics = jax.jit(fn)(params, mom, batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually moved
    delta = sum(float(jnp.abs(a.astype(jnp.float32) -
                              b.astype(jnp.float32)).sum())
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert delta > 0


def test_launch_serve_steps_roundtrip_host_mesh():
    cfg = get_config("tinyllama-1.1b").reduced()
    mesh = make_host_mesh()
    B, S = 2, 32
    shape = InputShape("p", S, B, "prefill")
    prefill = st.make_prefill_step(cfg, shape, mesh, param_dtype=jnp.float32)
    decode = st.make_decode_step(cfg, InputShape("d", S, B, "decode"), mesh)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    with compat.set_mesh(mesh):
        last, cache = jax.jit(prefill)(params, {"tokens": toks[:, :-1]})
        logits, cache = jax.jit(decode)(
            params, {"tokens": toks[:, -1:],
                     "positions": jnp.full((B,), S - 1, jnp.int32),
                     "cache": cache})
    full, _, _ = T.forward(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(logits[:, :cfg.vocab_size]),
                               np.asarray(full[:, -1, :cfg.vocab_size]),
                               atol=2e-3)


def test_dt_objective_train_step():
    """The paper's DT objective wired through the launch train step."""
    cfg = get_config("qwen2-0.5b").reduced()
    mesh = make_host_mesh()
    shape = InputShape("t", 32, 4, "train")
    fn, _ = st.make_train_step(cfg, shape, mesh, objective="dt", n_micro=1)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    mom = st.init_momentum(params)
    key = jax.random.PRNGKey(2)
    batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab_size),
             "blur": jnp.ones((4,))}
    with compat.set_mesh(mesh):
        p2, _, metrics = jax.jit(fn)(params, mom, batch)
    assert np.isfinite(float(metrics["loss"]))
