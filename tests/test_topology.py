"""Topology layer: SingleRSU / MultiRSU / HandoverMultiRSU equivalences.

The aggregation path in every test here is the fused Pallas `wagg` kernel
in interpret mode (forced via `wagg_backend("interpret")`) — the same
kernel the TPU path compiles, so the trainer's hot aggregation loop is
exercised end to end on CPU.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import aggregation as agg
from repro.core.federation import FLConfig, FederatedTrainer
from repro.core.mobility import MobilityModel
from repro.core.topology import (TOPOLOGIES, HandoverMultiRSU, MultiRSU,
                                 SingleRSU)
from repro.data.synthetic import make_dataset, partition_iid
from repro.models.resnet import init_resnet

BASE_CFG = FLConfig(n_vehicles=6, vehicles_per_round=2, batch_size=16,
                    rounds=2, local_iters=1, lr=0.3, seed=7)


@pytest.fixture(scope="module")
def tiny_world():
    x, y = make_dataset(n_per_class=40, seed=0)
    parts = partition_iid(y, 6)
    tree = init_resnet(get_config("resnet18-cifar"), jax.random.PRNGKey(0))
    return [x[p] for p in parts], tree


def _assert_trees_close(t1, t2, atol=1e-4):
    for l1, l2 in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=atol)


def test_multi_rsu_one_matches_single_rsu(tiny_world, monkeypatch):
    """MultiRSU(n_rsus=1) is the paper loop — identical round outputs —
    and the aggregation runs through the Pallas kernel."""
    data, tree = tiny_world
    from repro.kernels import ops as kops
    calls = {"n": 0}
    real = kops.wagg_flat

    def spy(stacked, w, interpret=None, mask=None):
        calls["n"] += 1
        return real(stacked, w, interpret, mask=mask)

    monkeypatch.setattr(kops, "wagg_flat", spy)
    with agg.wagg_backend("interpret"):
        tr_s = FederatedTrainer(BASE_CFG, tree, data, topology=SingleRSU())
        tr_m = FederatedTrainer(BASE_CFG, tree, data,
                                topology=MultiRSU(n_rsus=1))
        r_s = tr_s.round(0)
        r_m = tr_m.round(0)
    assert calls["n"] >= 2, "aggregation did not go through the wagg kernel"
    np.testing.assert_allclose(r_s["loss"], r_m["loss"], rtol=1e-5)
    assert r_s["velocities"] == r_m["velocities"]
    _assert_trees_close(tr_s.global_tree, tr_m.global_tree)


def test_hierarchical_equals_flat_through_trainer(tiny_world):
    """Equal blur + count-scaled level-2 weights + equal cohort sizes:
    the two-level MultiRSU round coincides with the flat SingleRSU round
    (the `hierarchical_equals_flat` condition, driven through the trainer)."""
    data, tree = tiny_world
    cfg = dataclasses.replace(BASE_CFG, vehicles_per_round=4)
    mob = MobilityModel(sigma=1e-4)       # near-constant velocity: equal blur
    with agg.wagg_backend("interpret"):
        tr_s = FederatedTrainer(cfg, tree, data, mobility=mob,
                                topology=SingleRSU())
        tr_m = FederatedTrainer(cfg, tree, data, mobility=mob,
                                topology=MultiRSU(n_rsus=2, count_scaled=True))
        r_s = tr_s.round(0)
        r_m = tr_m.round(0)
    assert r_m["rsu_sizes"] == [2, 2]
    np.testing.assert_allclose(r_s["loss"], r_m["loss"], rtol=1e-5)
    _assert_trees_close(tr_s.global_tree, tr_m.global_tree)


def test_handover_migrates_and_syncs(tiny_world):
    """Vehicles cross RSU boundaries between download and upload; RSU
    models diverge between syncs and re-converge on sync rounds."""
    data, tree = tiny_world
    cfg = dataclasses.replace(BASE_CFG, vehicles_per_round=3, rounds=4)
    topo = HandoverMultiRSU(n_rsus=2, rsu_range=200.0, round_duration=50.0,
                            stale_discount=0.5, sync_every=2)
    with agg.wagg_backend("interpret"):
        tr = FederatedTrainer(cfg, tree, data, topology=topo)
        hist = [tr.round(r, parallel=False) for r in range(4)]
    assert all(np.isfinite(h["loss"]) for h in hist)
    # at ~29 m/s for 50 s a vehicle crosses 1450 m >> the 200 m range:
    # handovers must occur over 12 participant draws
    assert sum(h["n_handovers"] for h in hist) >= 1
    assert [h["synced"] for h in hist] == [False, True, False, True]
    # after a sync round every RSU holds the merged model, and the
    # evaluation snapshot coincides with it (motion state lives in FLState)
    rsu_models = tr.state.topo["rsu_models"]
    _assert_trees_close(rsu_models[0], rsu_models[1], atol=0)
    _assert_trees_close(topo.region_view(tr.state), rsu_models[0], atol=1e-5)
    # positions stayed on the ring road
    positions = tr.state.topo["positions"]
    assert np.all(positions >= 0) and np.all(positions < topo.road_length)


def test_handover_bucketed_vmapped_matches_sequential(tiny_world):
    """The default handover path (vmapped cohorts padded to power-of-two
    buckets, masked-weight aggregation) is BIT-exact with the sequential
    per-client reference — every FLState leaf and every record field —
    and stays within the bucketing compile bound."""
    from repro.core.clients import (cohort_step_cache_size,
                                    reset_cohort_step_caches)
    from repro.core.scenario import run_round

    data, tree = tiny_world
    cfg = dataclasses.replace(BASE_CFG, vehicles_per_round=4, rounds=4)
    topo = HandoverMultiRSU(n_rsus=2, rsu_range=200.0, round_duration=50.0,
                            stale_discount=0.5, sync_every=2)
    tr_p = FederatedTrainer(cfg, tree, data, topology=topo)
    tr_s = FederatedTrainer(cfg, tree, data, topology=topo)
    reset_cohort_step_caches()
    sp, ss = tr_p.state, tr_s.state
    with agg.wagg_backend("interpret"):
        for _ in range(4):
            sp, rp = run_round(sp, tr_p.scenario, parallel=True)
            ss, rs = run_round(ss, tr_s.scenario, parallel=False)
            assert rp == rs
            for lp, ls in zip(jax.tree.leaves(sp.to_tree()),
                              jax.tree.leaves(ss.to_tree())):
                np.testing.assert_array_equal(np.asarray(lp), np.asarray(ls))
    # download-group sizes are 1..4, so at most buckets {1, 2, 4} compile
    assert cohort_step_cache_size(cfg) <= \
        int(np.ceil(np.log2(cfg.vehicles_per_round))) + 1


def test_mesh_two_stage_collective_through_trainer(tiny_world):
    """mesh_aggregate=True routes the region merge through
    two_stage_weighted_psum under shard_map (1 RSU x 1 vehicle on the
    single CPU device; larger meshes need more devices)."""
    data, tree = tiny_world
    cfg = dataclasses.replace(BASE_CFG, vehicles_per_round=1)
    tr_h = FederatedTrainer(cfg, tree, data,
                            topology=MultiRSU(n_rsus=1, mesh_aggregate=False))
    tr_m = FederatedTrainer(cfg, tree, data,
                            topology=MultiRSU(n_rsus=1, mesh_aggregate=True))
    r_h = tr_h.round(0, parallel=False)
    r_m = tr_m.round(0, parallel=False)
    np.testing.assert_allclose(r_h["loss"], r_m["loss"], rtol=1e-5)
    _assert_trees_close(tr_h.global_tree, tr_m.global_tree)


def test_topology_validation(tiny_world):
    data, tree = tiny_world
    cfg = dataclasses.replace(BASE_CFG, aggregator="fedavg")
    with pytest.raises(ValueError, match="flsimco"):
        FederatedTrainer(cfg, tree, data, topology=MultiRSU(n_rsus=2))
    with pytest.raises(ValueError, match="flsimco"):
        FederatedTrainer(cfg, tree, data, topology=HandoverMultiRSU())
    cfg = dataclasses.replace(BASE_CFG, normalize_weights=False)
    with pytest.raises(ValueError, match="normalize"):
        FederatedTrainer(cfg, tree, data, topology=MultiRSU(n_rsus=2))
    with pytest.raises(ValueError):
        MultiRSU(n_rsus=0)
    with pytest.raises(ValueError):
        HandoverMultiRSU(stale_discount=2.0)
    assert set(TOPOLOGIES) == {"single", "multi", "handover"}


def test_wagg_backend_switch_roundtrip():
    assert agg.set_wagg_backend("tree") in agg._WAGG_BACKENDS
    agg.set_wagg_backend("auto")
    with pytest.raises(ValueError):
        agg.set_wagg_backend("nope")
    with agg.wagg_backend("interpret"):
        assert agg._resolve_wagg_backend() == "interpret"
    assert agg._resolve_wagg_backend() in ("tree", "fused")
